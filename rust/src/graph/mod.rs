//! Communication-graph substrate for the fixed-graph baselines
//! (ClippedGossip, CS+, GTS — paper Appendix C.2).
//!
//! The paper's comparison protocol: for RPEL parameters (n, s), generate a
//! **random connected graph with the same number of edges** K = n·s/2 —
//! a uniform random spanning tree (random Prüfer sequence) plus uniformly
//! random extra edges — then run the baseline's gossip update on it with
//! Metropolis–Hastings weights. Remark C.1: adversarial positions are
//! random on the graph (no honest-subgraph pre-construction).

use crate::util::rng::Rng;

/// An undirected simple graph on nodes 0..n.
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    adj: Vec<Vec<usize>>, // sorted neighbor lists
    pub edges: usize,
}

impl Graph {
    pub fn empty(n: usize) -> Self {
        Graph {
            n,
            adj: vec![Vec::new(); n],
            edges: 0,
        }
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        self.adj[a].binary_search(&b).is_ok()
    }

    /// Insert an undirected edge, ignoring self-loops and duplicates.
    /// Returns true if the edge was new.
    pub fn add_edge(&mut self, a: usize, b: usize) -> bool {
        if a == b || a >= self.n || b >= self.n || self.has_edge(a, b) {
            return false;
        }
        let pa = self.adj[a].binary_search(&b).unwrap_err();
        self.adj[a].insert(pa, b);
        let pb = self.adj[b].binary_search(&a).unwrap_err();
        self.adj[b].insert(pb, a);
        self.edges += 1;
        true
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut queue = std::collections::VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.n
    }

    /// Uniform random labeled spanning tree via a random Prüfer sequence
    /// (every labeled tree equally likely — the distribution family behind
    /// networkx's `random_spanning_tree` usage in the paper's Appendix C.2).
    pub fn random_tree(n: usize, rng: &mut Rng) -> Graph {
        let mut g = Graph::empty(n);
        if n <= 1 {
            return g;
        }
        if n == 2 {
            g.add_edge(0, 1);
            return g;
        }
        let prufer: Vec<usize> = (0..n - 2).map(|_| rng.index(n)).collect();
        let mut degree = vec![1usize; n];
        for &p in &prufer {
            degree[p] += 1;
        }
        // standard Prüfer decoding with a min-heap replaced by a scan-free
        // "pointer + leaf set" approach (n is small; BTreeSet is fine)
        let mut leaves: std::collections::BTreeSet<usize> = (0..n)
            .filter(|&i| degree[i] == 1)
            .collect();
        for &p in &prufer {
            let leaf = *leaves.iter().next().unwrap();
            leaves.remove(&leaf);
            g.add_edge(leaf, p);
            degree[p] -= 1;
            if degree[p] == 1 {
                leaves.insert(p);
            }
        }
        let mut it = leaves.iter();
        let (a, b) = (*it.next().unwrap(), *it.next().unwrap());
        g.add_edge(a, b);
        g
    }

    /// The paper's random connected graph: spanning tree + uniformly random
    /// extra edges until reaching `target_edges` (≥ n−1). Saturates at the
    /// complete graph.
    pub fn random_connected(n: usize, target_edges: usize, rng: &mut Rng) -> Graph {
        let max_edges = n * (n - 1) / 2;
        let target = target_edges.clamp(n.saturating_sub(1), max_edges);
        let mut g = Graph::random_tree(n, rng);
        while g.edges < target {
            let a = rng.index(n);
            let b = rng.index(n);
            g.add_edge(a, b);
        }
        g
    }

    /// Metropolis–Hastings gossip weights: W[i][j] = 1/(1+max(deg_i,deg_j))
    /// for edges, W[i][i] = 1 − Σ_j W[i][j]. Symmetric, doubly stochastic —
    /// the standard gossip matrix for decentralized SGD baselines.
    pub fn metropolis_weights(&self) -> Vec<Vec<(usize, f64)>> {
        (0..self.n)
            .map(|i| {
                let mut row: Vec<(usize, f64)> = self.adj[i]
                    .iter()
                    .map(|&j| {
                        (
                            j,
                            1.0 / (1.0 + self.degree(i).max(self.degree(j)) as f64),
                        )
                    })
                    .collect();
                let off: f64 = row.iter().map(|(_, w)| w).sum();
                row.push((i, 1.0 - off));
                row.sort_unstable_by_key(|&(j, _)| j);
                row
            })
            .collect()
    }

    /// Max degree (bench/diagnostic).
    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|i| self.degree(i)).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_properties() {
        let mut rng = Rng::new(1);
        for n in [2usize, 3, 10, 50] {
            let g = Graph::random_tree(n, &mut rng);
            assert_eq!(g.edges, n - 1, "n={n}");
            assert!(g.is_connected(), "n={n}");
        }
    }

    #[test]
    fn tree_distribution_hits_different_shapes() {
        // over many draws of a 4-node tree, both stars and paths must occur
        let mut rng = Rng::new(2);
        let (mut stars, mut paths) = (0, 0);
        for _ in 0..200 {
            let g = Graph::random_tree(4, &mut rng);
            match g.max_degree() {
                3 => stars += 1,
                2 => paths += 1,
                _ => {}
            }
        }
        assert!(stars > 0 && paths > 0, "stars={stars} paths={paths}");
    }

    #[test]
    fn connected_graph_edge_budget() {
        let mut rng = Rng::new(3);
        let g = Graph::random_connected(30, 30 * 15 / 2, &mut rng);
        assert_eq!(g.edges, 225);
        assert!(g.is_connected());
    }

    #[test]
    fn connected_graph_saturates_at_complete() {
        let mut rng = Rng::new(4);
        let g = Graph::random_connected(6, 1000, &mut rng);
        assert_eq!(g.edges, 15);
        for i in 0..6 {
            assert_eq!(g.degree(i), 5);
        }
    }

    #[test]
    fn edge_budget_below_tree_clamps() {
        let mut rng = Rng::new(5);
        let g = Graph::random_connected(10, 3, &mut rng);
        assert_eq!(g.edges, 9); // spanning tree minimum
        assert!(g.is_connected());
    }

    #[test]
    fn no_self_loops_or_duplicates() {
        let mut g = Graph::empty(4);
        assert!(!g.add_edge(1, 1));
        assert!(g.add_edge(0, 1));
        assert!(!g.add_edge(1, 0));
        assert_eq!(g.edges, 1);
    }

    #[test]
    fn metropolis_rows_are_stochastic_and_symmetric() {
        let mut rng = Rng::new(6);
        let g = Graph::random_connected(12, 30, &mut rng);
        let w = g.metropolis_weights();
        for i in 0..12 {
            let sum: f64 = w[i].iter().map(|(_, v)| v).sum();
            assert!((sum - 1.0).abs() < 1e-12);
            for &(j, wij) in &w[i] {
                if j != i {
                    let wji = w[j]
                        .iter()
                        .find(|&&(k, _)| k == i)
                        .map(|&(_, v)| v)
                        .unwrap();
                    assert!((wij - wji).abs() < 1e-12);
                    assert!(wij > 0.0);
                }
            }
        }
    }

    #[test]
    fn metropolis_self_weight_nonnegative() {
        let mut rng = Rng::new(7);
        let g = Graph::random_connected(20, 40, &mut rng);
        for (i, row) in g.metropolis_weights().iter().enumerate() {
            let self_w = row.iter().find(|&&(j, _)| j == i).map(|&(_, v)| v).unwrap();
            assert!(self_w >= 0.0, "node {i} self weight {self_w}");
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = Graph::random_connected(15, 40, &mut Rng::new(8));
        let b = Graph::random_connected(15, 40, &mut Rng::new(8));
        for i in 0..15 {
            assert_eq!(a.neighbors(i), b.neighbors(i));
        }
    }
}
