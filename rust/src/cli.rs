//! Command-line argument parsing (clap is not in the offline crate set).
//!
//! Grammar: `rpel <command> [--flag value | --flag=value | --switch] ...`.
//! Typed accessors return errors naming the flag, and unknown-flag
//! detection is driven by a per-command allowlist in `main.rs`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key) || self.flags.contains_key(key)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("--{key} expects a number, got '{v}'")),
        }
    }

    /// Comma-separated integer list: `--grid 5,10,15`.
    pub fn get_u64_list(&self, key: &str) -> Result<Option<Vec<u64>>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<u64>()
                        .map_err(|_| format!("--{key} expects integers, got '{p}'"))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }

    /// Reject flags/switches not in the allowlist (typo detection).
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.flags.keys().chain(self.switches.iter()) {
            if !known.contains(&k.as_str()) {
                return Err(format!(
                    "unknown flag --{k}; known: {}",
                    known
                        .iter()
                        .map(|k| format!("--{k}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn command_flags_switches() {
        let a = parse(&["figure", "--id", "fig1L", "--scale=paper", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("figure"));
        assert_eq!(a.get("id"), Some("fig1L"));
        assert_eq!(a.get("scale"), Some("paper"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["eaf", "--n", "100", "--frac", "0.1", "--grid", "5,10,15"]);
        assert_eq!(a.get_usize("n").unwrap(), Some(100));
        assert_eq!(a.get_f64("frac").unwrap(), Some(0.1));
        assert_eq!(a.get_u64_list("grid").unwrap(), Some(vec![5, 10, 15]));
        assert_eq!(a.get_usize("missing").unwrap(), None);
        assert!(a.get_usize("frac").is_err());
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["list", "--presets"]);
        assert!(a.has("presets"));
    }

    #[test]
    fn positional_args() {
        let a = parse(&["train", "config.toml", "--engine", "native"]);
        assert_eq!(a.positional, vec!["config.toml"]);
        assert_eq!(a.get("engine"), Some("native"));
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse(&["train", "--engin", "native"]);
        let err = a.check_known(&["engine", "config"]).unwrap_err();
        assert!(err.contains("engin"));
        parse(&["train", "--engine", "native"])
            .check_known(&["engine"])
            .unwrap();
    }
}
