//! Per-figure experiment presets — the executable form of the paper's
//! evaluation section (DESIGN.md §6 experiment index).
//!
//! Every figure of the paper maps to a [`Figure`] whose
//! [`Figure::series`] returns the concrete run configs (or hypergeometric
//! scenarios for Figure 3). Two scales:
//!
//! * [`Scale::Paper`] — the paper's exact (n, b, s, T, batch, LR, α).
//!   Architectures remain the reduced MLPs (DESIGN.md §Substitutions; the
//!   paper CNNs exist in `python/compile/model.py` and lower with
//!   `--scale paper` artifacts, but full CNN training at n=100/T=2000 does
//!   not fit the 1-core budget).
//! * [`Scale::Tiny`] — the same experiment *shape* (who wins, orderings,
//!   breakdowns) at a budget that runs in seconds; used by CI/benches.
//!
//! Tables 1 and 2 of the paper are the hyper-parameter tables; they are
//! encoded directly in the `base_*` constructors below and printed by
//! `rpel list --presets`.

use super::{Compression, EngineKind, ExperimentConfig, RuleChoice, Topology};
use crate::aggregation::gossip::GossipRuleKind;
use crate::aggregation::RuleKind;
use crate::attacks::AttackKind;
use crate::data::TaskKind;

/// Experiment scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Tiny,
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        Some(match s {
            "tiny" => Scale::Tiny,
            "paper" => Scale::Paper,
            _ => return None,
        })
    }
}

/// One paper figure (or appendix figure).
#[derive(Clone, Copy, Debug)]
pub struct Figure {
    pub id: &'static str,
    pub title: &'static str,
    /// What shape the paper's curve has — checked in EXPERIMENTS.md.
    pub expectation: &'static str,
}

/// What a figure runs.
pub enum FigureSeries {
    /// Training curves: one config per plotted line.
    Training(Vec<ExperimentConfig>),
    /// Figure 3: pure hypergeometric simulation scenarios.
    Eaf(Vec<EafScenario>),
}

/// One Figure-3 scenario: sweep `grid` values of s.
#[derive(Clone, Debug)]
pub struct EafScenario {
    pub label: String,
    pub n: u64,
    pub b: u64,
    pub t: u64,
    pub grid: Vec<u64>,
    pub sims: usize,
}

// ---------------------------------------------------------------------------
// Base configs (Tables 1 and 2)
// ---------------------------------------------------------------------------

/// Table 1, MNIST column. Paper: n∈{100,30}, b∈{10,6}, α=1, CNN, lr 0.5,
/// batch 25, momentum 0.9, wd 1e-4, T=200.
fn base_mnist(scale: Scale) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::MnistLike);
    cfg.alpha = 1.0;
    cfg.momentum = 0.9;
    cfg.weight_decay = 1e-4;
    cfg.lr_schedule = vec![(0, 0.5)];
    cfg.batch = 25;
    cfg.rounds = 200;
    cfg.eval_every = 10;
    cfg.engine = EngineKind::Hlo;
    match scale {
        Scale::Paper => {
            cfg.samples_per_node = 512;
            cfg.test_samples = 512;
        }
        Scale::Tiny => {
            cfg.rounds = 60;
            cfg.batch = 16;
            cfg.samples_per_node = 96;
            cfg.test_samples = 256;
            cfg.eval_every = 6;
            cfg.engine = EngineKind::Native;
        }
    }
    cfg
}

/// Table 1, CIFAR-10 column. Paper: n=20, b=3, α=10 (low heterogeneity),
/// staircase LR, batch 50, momentum 0.99, wd 1e-2, T=2000.
fn base_cifar(scale: Scale) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::CifarLike);
    cfg.n = 20;
    cfg.b = 3;
    cfg.alpha = 10.0;
    cfg.momentum = 0.99;
    cfg.weight_decay = 1e-2;
    cfg.batch = 50;
    cfg.engine = EngineKind::Hlo;
    match scale {
        Scale::Paper => {
            cfg.rounds = 2000;
            cfg.lr_schedule = vec![(0, 0.5), (500, 0.1), (1000, 0.02), (1500, 0.004)];
            cfg.samples_per_node = 512;
            cfg.test_samples = 512;
            cfg.eval_every = 50;
        }
        Scale::Tiny => {
            cfg.rounds = 80;
            cfg.lr_schedule = vec![(0, 0.5), (20, 0.1), (40, 0.02), (60, 0.004)];
            cfg.batch = 16;
            cfg.samples_per_node = 96;
            cfg.test_samples = 256;
            cfg.eval_every = 8;
            cfg.engine = EngineKind::Native;
            // β = 0.99 needs ~1/(1−β) ≈ 100 rounds just to saturate the
            // momentum — fine at the paper's T = 2000, not at T = 80.
            // Scale the momentum time-constant with the horizon.
            cfg.momentum = 0.9;
            cfg.weight_decay = 1e-3;
        }
    }
    cfg
}

/// Table 2 (FEMNIST): n=30, b=3, α=10, lr 0.1, batch 50, momentum 0.99,
/// wd 1e-4, T=500.
fn base_femnist(scale: Scale) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::FemnistLike);
    cfg.n = 30;
    cfg.b = 3;
    cfg.alpha = 10.0;
    cfg.momentum = 0.99;
    cfg.weight_decay = 1e-4;
    cfg.lr_schedule = vec![(0, 0.1)];
    cfg.batch = 50;
    cfg.engine = EngineKind::Hlo;
    match scale {
        Scale::Paper => {
            cfg.rounds = 500;
            cfg.samples_per_node = 512;
            cfg.test_samples = 512;
            cfg.eval_every = 25;
        }
        Scale::Tiny => {
            cfg.rounds = 80;
            cfg.lr_schedule = vec![(0, 0.2)];
            cfg.batch = 16;
            cfg.samples_per_node = 96;
            cfg.test_samples = 256;
            cfg.eval_every = 8;
            cfg.engine = EngineKind::Native;
            // see base_cifar: momentum horizon scaled with T
            cfg.momentum = 0.9;
        }
    }
    cfg
}

fn with_attacks(
    base: &ExperimentConfig,
    fig: &str,
    attacks: &[AttackKind],
) -> Vec<ExperimentConfig> {
    attacks
        .iter()
        .map(|&a| {
            let mut c = base.clone();
            c.attack = a;
            c.name = format!("{fig}/{}", a.name());
            c
        })
        .collect()
}

/// The paper's main-figure attack panel (SF, FOE, ALIE + no-attack ref).
const PANEL: [AttackKind; 4] = [
    AttackKind::None,
    AttackKind::SignFlip,
    AttackKind::Foe,
    AttackKind::Alie,
];

// ---------------------------------------------------------------------------
// Figure registry
// ---------------------------------------------------------------------------

const FIGURES: &[Figure] = &[
    Figure { id: "fig1L", title: "MNIST, n=100 b=10 s=15 (EAF .44)", expectation: "RPEL reaches high accuracy (>90% on MNIST) under SF/FOE/ALIE; close to the no-attack curve" },
    Figure { id: "fig1R", title: "MNIST, n=30 b=6 s=15 (EAF .375)", expectation: "same as fig1L at 20% Byzantine" },
    Figure { id: "fig2L", title: "CIFAR-10, n=20 b=3 s=6 (EAF .43)", expectation: "≈75% accuracy under all three attacks despite sparse pulls" },
    Figure { id: "fig2R", title: "CIFAR-10, n=20 b=3 s=19 (all-to-all)", expectation: "s=6 (fig2L) matches s=19 accuracy at ~1/3 the messages" },
    Figure { id: "fig3", title: "Effective adversarial fraction vs s", expectation: "EAF decreases with s; required s grows ~log n at fixed b/n" },
    Figure { id: "fig4", title: "Avg accuracy vs fixed-graph baselines (ALIE)", expectation: "RPEL ≥ baselines; gap largest at low s (sparse)" },
    Figure { id: "fig5", title: "Worst-client accuracy vs baselines (ALIE)", expectation: "RPEL's worst client consistently beats baselines (fairness)" },
    Figure { id: "fig6", title: "Avg accuracy vs baselines (Dissensus)", expectation: "same ordering as fig4 under Dissensus" },
    Figure { id: "fig7", title: "Worst-client accuracy vs baselines (Dissensus)", expectation: "same ordering as fig5 under Dissensus" },
    Figure { id: "fig8", title: "CIFAR heterogeneity ablation (α=0.5, 1)", expectation: "RPEL remains robust at higher heterogeneity; accuracy degrades gracefully as α shrinks" },
    Figure { id: "fig9", title: "CIFAR Dissensus, α=1, 1 local step", expectation: "robust at s=6 and s=19, avg and worst" },
    Figure { id: "fig10", title: "CIFAR Dissensus, α=1, 3 local steps", expectation: "faster convergence than fig9, same robustness" },
    Figure { id: "fig11", title: "MNIST n=100 f=8 s=15", expectation: "like fig1L with smaller b: higher margins" },
    Figure { id: "fig12", title: "MNIST n=30 f=5 s=15", expectation: "like fig1R with smaller b" },
    Figure { id: "fig13", title: "CIFAR n=20 f=2 s=6", expectation: "like fig2L with smaller b" },
    Figure { id: "fig14", title: "CIFAR n=20 f=2 s=19", expectation: "like fig2R with smaller b" },
    Figure { id: "fig15", title: "CIFAR f=3 s=6, 3 local steps", expectation: "faster convergence to 75%+ than 1 local step" },
    Figure { id: "fig16", title: "CIFAR f=3 s=10, 3 local steps", expectation: "s=10 ≈ s=6 ≈ all-to-all accuracy" },
    Figure { id: "fig17", title: "CIFAR f=3 s=19, 3 local steps", expectation: "all-to-all no better than s=6" },
    Figure { id: "fig18", title: "FEMNIST n=30 f=0 s=6", expectation: "attack-free reference run" },
    Figure { id: "fig19", title: "FEMNIST n=30 f=0 s=6, 3 local steps", expectation: "attack-free, faster convergence" },
    Figure { id: "fig20", title: "FEMNIST n=30 f=3 s=6", expectation: "robust accuracy close to f=0 reference" },
    Figure { id: "fig21", title: "FEMNIST n=30 f=3 s=6, 3 local steps", expectation: "robust, faster convergence" },
    Figure { id: "figWire", title: "Accuracy vs wire bits (none/f16/q8 × attack)", expectation: "f16 tracks the uncompressed curve; q8 stays within a small gap under SF/FOE/ALIE — the codec is a modeled protocol knob, not FP noise" },
];

/// All registered figures.
pub fn all_figures() -> &'static [Figure] {
    FIGURES
}

/// Look up a figure by id.
pub fn figure(id: &str) -> Option<Figure> {
    FIGURES.iter().copied().find(|f| f.id == id)
}

impl Figure {
    /// Build the concrete series for this figure at the given scale.
    pub fn series(&self, scale: Scale) -> FigureSeries {
        build_series(self.id, scale)
    }
}

fn scaled_n(scale: Scale, n_paper: usize, b_paper: usize) -> (usize, usize) {
    match scale {
        Scale::Paper => (n_paper, b_paper),
        Scale::Tiny => {
            if n_paper <= 30 {
                (n_paper, b_paper)
            } else {
                // preserve the Byzantine fraction at n=30
                let n = 30;
                let b = (b_paper * n + n_paper / 2) / n_paper;
                (n, b)
            }
        }
    }
}

fn build_series(id: &str, scale: Scale) -> FigureSeries {
    match id {
        "fig1L" => {
            let mut base = base_mnist(scale);
            let (n, b) = scaled_n(scale, 100, 10);
            base.n = n;
            base.b = b;
            base.topology = Topology::Epidemic { s: 15 };
            base.bhat = if scale == Scale::Paper { Some(7) } else { None };
            FigureSeries::Training(with_attacks(&base, "fig1L", &PANEL))
        }
        "fig1R" => {
            let mut base = base_mnist(scale);
            base.n = 30;
            base.b = 6;
            base.topology = Topology::Epidemic { s: 15 };
            base.bhat = if scale == Scale::Paper { Some(6) } else { None };
            FigureSeries::Training(with_attacks(&base, "fig1R", &PANEL))
        }
        "fig2L" | "fig2R" => {
            let mut base = base_cifar(scale);
            let s = if id == "fig2L" { 6 } else { 19 };
            base.topology = Topology::Epidemic { s };
            base.bhat = Some(3);
            FigureSeries::Training(with_attacks(&base, id, &PANEL))
        }
        "fig3" => {
            let sims = 5;
            let t = 200;
            FigureSeries::Eaf(vec![
                EafScenario {
                    label: "n=100, b=10 (10%)".into(),
                    n: 100,
                    b: 10,
                    t,
                    grid: vec![5, 10, 15, 20, 25, 30, 40, 60],
                    sims,
                },
                EafScenario {
                    label: "n=10k, b=1k (10%)".into(),
                    n: 10_000,
                    b: 1_000,
                    t,
                    grid: vec![10, 15, 20, 25, 30, 40],
                    sims,
                },
                EafScenario {
                    label: "n=10k, b=2k (20%)".into(),
                    n: 10_000,
                    b: 2_000,
                    t,
                    grid: vec![10, 15, 20, 25, 30, 40, 60],
                    sims,
                },
                EafScenario {
                    label: "n=100k, b=10k (10%)".into(),
                    n: 100_000,
                    b: 10_000,
                    t,
                    grid: vec![10, 15, 20, 25, 30, 40],
                    sims,
                },
            ])
        }
        "fig4" | "fig5" | "fig6" | "fig7" => {
            // fig4/5 = ALIE (avg/worst); fig6/7 = Dissensus (avg/worst).
            // Same runs; avg vs worst is a reporting choice on the history.
            let attack = if id == "fig4" || id == "fig5" {
                AttackKind::Alie
            } else {
                AttackKind::Dissensus
            };
            let mut base = base_mnist(scale);
            base.n = 30;
            base.b = 6;
            base.attack = attack;
            base.engine = EngineKind::Native; // wide sweep: native engine
            let s_grid: &[usize] = match scale {
                Scale::Paper => &[4, 6, 10, 15],
                Scale::Tiny => &[4, 6, 10],
            };
            let mut series = Vec::new();
            for &s in s_grid {
                // RPEL — at very sparse s with 20% Byzantine the Algorithm-2
                // b̂ can hit the 1/2 breakdown (the regime figs 4–5 probe);
                // run best-effort with the maximum feasible trim b̂ = ⌊s/2⌋
                // instead of refusing, exactly to expose that degradation.
                let mut c = base.clone();
                c.topology = Topology::Epidemic { s };
                c.rule = RuleChoice::Epidemic(RuleKind::NnmCwtm);
                c.bhat = Some(s / 2);
                c.name = format!("{id}/rpel/s{s}");
                series.push(c);
                // fixed-graph baselines at the same message budget
                for g in [
                    GossipRuleKind::CsPlus,
                    GossipRuleKind::ClippedGossip,
                    GossipRuleKind::Gts,
                ] {
                    let mut c = base.clone();
                    c.topology = Topology::FixedGraph {
                        edges: base.n * s / 2,
                    };
                    c.rule = RuleChoice::Gossip(g);
                    c.name = format!("{id}/{}/s{s}", g.name());
                    series.push(c);
                }
            }
            FigureSeries::Training(series)
        }
        "fig8" => {
            let mut series = Vec::new();
            for alpha in [0.5, 1.0] {
                for s in [6usize, 19] {
                    let mut base = base_cifar(scale);
                    base.alpha = alpha;
                    base.topology = Topology::Epidemic { s };
                    base.bhat = Some(3);
                    for mut c in with_attacks(
                        &base,
                        &format!("fig8/a{alpha}/s{s}"),
                        &[AttackKind::SignFlip, AttackKind::Foe, AttackKind::Alie],
                    ) {
                        c.name = c.name.clone();
                        series.push(c);
                    }
                }
            }
            FigureSeries::Training(series)
        }
        "fig9" | "fig10" => {
            let local = if id == "fig9" { 1 } else { 3 };
            let mut series = Vec::new();
            for s in [6usize, 19] {
                let mut base = base_cifar(scale);
                base.alpha = 1.0;
                base.local_steps = local;
                base.topology = Topology::Epidemic { s };
                base.bhat = Some(3);
                base.attack = AttackKind::Dissensus;
                base.name = format!("{id}/dissensus/s{s}");
                series.push(base);
            }
            FigureSeries::Training(series)
        }
        "fig11" | "fig12" => {
            let mut base = base_mnist(scale);
            let (n, b) = if id == "fig11" {
                scaled_n(scale, 100, 8)
            } else {
                (30, 5)
            };
            base.n = n;
            base.b = b;
            base.topology = Topology::Epidemic { s: 15 };
            FigureSeries::Training(with_attacks(&base, id, &PANEL))
        }
        "fig13" | "fig14" => {
            let mut base = base_cifar(scale);
            base.b = 2;
            base.topology = Topology::Epidemic {
                s: if id == "fig13" { 6 } else { 19 },
            };
            FigureSeries::Training(with_attacks(&base, id, &PANEL))
        }
        "fig15" | "fig16" | "fig17" => {
            let mut base = base_cifar(scale);
            base.local_steps = 3;
            base.topology = Topology::Epidemic {
                s: match id {
                    "fig15" => 6,
                    "fig16" => 10,
                    _ => 19,
                },
            };
            base.bhat = Some(3);
            FigureSeries::Training(with_attacks(&base, id, &PANEL))
        }
        "fig18" | "fig19" => {
            let mut base = base_femnist(scale);
            base.b = 0;
            base.attack = AttackKind::None;
            base.local_steps = if id == "fig18" { 1 } else { 3 };
            base.topology = Topology::Epidemic { s: 6 };
            base.name = format!("{id}/none");
            FigureSeries::Training(vec![base])
        }
        "figWire" => {
            // Accuracy-vs-bits sweep for the wire codec. Decoding is part of
            // the protocol (every consumer aggregates the decoded bits), so
            // each compression level is its own deterministic trajectory; the
            // sweep measures how much accuracy the f16/q8 rounding costs under
            // each attack, relative to the uncompressed reference.
            let mut base = base_mnist(scale);
            base.n = 30;
            base.b = 6;
            base.topology = Topology::Epidemic { s: 15 };
            let mut series = Vec::new();
            for comp in [Compression::None, Compression::F16, Compression::Q8] {
                let mut b = base.clone();
                b.compression = comp;
                series.extend(with_attacks(
                    &b,
                    &format!("figWire/{}", comp.name()),
                    &PANEL,
                ));
            }
            FigureSeries::Training(series)
        }
        "fig20" | "fig21" => {
            let mut base = base_femnist(scale);
            base.local_steps = if id == "fig20" { 1 } else { 3 };
            base.topology = Topology::Epidemic { s: 6 };
            FigureSeries::Training(with_attacks(&base, id, &PANEL))
        }
        other => panic!("unknown figure id '{other}' (registry bug)"),
    }
}

/// The quickstart config used by `examples/quickstart.rs` and smoke tests.
pub fn quickstart_config() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = "quickstart".into();
    cfg.n = 8;
    cfg.b = 1;
    cfg.topology = Topology::Epidemic { s: 7 };
    cfg.bhat = Some(2);
    cfg.rule = RuleChoice::Epidemic(RuleKind::NnmCwtm);
    cfg.attack = AttackKind::SignFlip;
    cfg.rounds = 40;
    cfg.batch = 8;
    cfg.samples_per_node = 64;
    cfg.test_samples = 128;
    cfg.eval_every = 5;
    cfg.engine = EngineKind::Native;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_figure_builds_and_validates_at_both_scales() {
        for fig in all_figures() {
            for scale in [Scale::Tiny, Scale::Paper] {
                match fig.series(scale) {
                    FigureSeries::Training(cfgs) => {
                        assert!(!cfgs.is_empty(), "{} empty", fig.id);
                        for c in cfgs {
                            c.validate()
                                .unwrap_or_else(|e| panic!("{} ({:?}): {e}", c.name, scale));
                        }
                    }
                    FigureSeries::Eaf(scens) => {
                        assert!(!scens.is_empty());
                        for s in scens {
                            assert!(s.b < s.n);
                            assert!(!s.grid.is_empty());
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn figure_lookup() {
        assert!(figure("fig1L").is_some());
        assert!(figure("fig3").is_some());
        assert!(figure("nope").is_none());
        assert_eq!(all_figures().len(), 24);
    }

    #[test]
    fn figwire_sweeps_every_compression_level() {
        let FigureSeries::Training(cfgs) = figure("figWire").unwrap().series(Scale::Tiny)
        else {
            panic!()
        };
        // 3 compression levels × the 4-attack panel
        assert_eq!(cfgs.len(), 12);
        for comp in [Compression::None, Compression::F16, Compression::Q8] {
            let matching: Vec<_> =
                cfgs.iter().filter(|c| c.compression == comp).collect();
            assert_eq!(matching.len(), 4, "{}", comp.name());
            for c in matching {
                assert!(c.name.starts_with(&format!("figWire/{}", comp.name())));
            }
        }
    }

    #[test]
    fn fig1l_matches_paper_at_paper_scale() {
        let FigureSeries::Training(cfgs) = figure("fig1L").unwrap().series(Scale::Paper)
        else {
            panic!()
        };
        let c = &cfgs[0];
        assert_eq!((c.n, c.b), (100, 10));
        assert_eq!(c.topology, Topology::Epidemic { s: 15 });
        assert_eq!(c.bhat, Some(7));
        assert_eq!(c.rounds, 200);
        assert_eq!(c.batch, 25);
        assert_eq!(c.momentum, 0.9);
    }

    #[test]
    fn fig2_paper_has_staircase_lr() {
        let FigureSeries::Training(cfgs) = figure("fig2L").unwrap().series(Scale::Paper)
        else {
            panic!()
        };
        assert_eq!(cfgs[0].lr_schedule.len(), 4);
        assert_eq!(cfgs[0].rounds, 2000);
        assert_eq!(cfgs[0].momentum, 0.99);
    }

    #[test]
    fn fig3_reaches_paper_scale() {
        let FigureSeries::Eaf(scens) = figure("fig3").unwrap().series(Scale::Paper) else {
            panic!()
        };
        assert!(scens.iter().any(|s| s.n == 100_000 && s.b == 10_000));
    }

    #[test]
    fn baseline_figures_match_message_budget() {
        let FigureSeries::Training(cfgs) = figure("fig4").unwrap().series(Scale::Tiny)
        else {
            panic!()
        };
        // for each s, RPEL and the baselines must have equal message budget
        for chunk in cfgs.chunks(4) {
            let budget = chunk[0].messages_per_round();
            for c in chunk {
                assert_eq!(c.messages_per_round(), budget, "{}", c.name);
            }
        }
    }

    #[test]
    fn quickstart_valid() {
        quickstart_config().validate().unwrap();
    }

    #[test]
    fn tiny_preserves_byzantine_fraction() {
        let FigureSeries::Training(cfgs) = figure("fig1L").unwrap().series(Scale::Tiny)
        else {
            panic!()
        };
        let c = &cfgs[0];
        assert_eq!(c.n, 30);
        assert_eq!(c.b, 3); // 10% preserved
    }
}
