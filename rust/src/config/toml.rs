//! Minimal TOML parser (serde/toml crates are not in the offline set).
//!
//! Supports the subset a config system needs: `[table]` and
//! `[table.subtable]` headers, `key = value` with strings, integers,
//! floats, booleans, and homogeneous inline arrays, plus `#` comments.
//! Values land in a flat `BTreeMap<String, TomlValue>` keyed by dotted
//! path (`"training.lr"`), which keeps lookups trivial for the schema
//! layer in [`super::file`].

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with line number. (Manual `Display`/`Error` impls —
/// `thiserror` is not in the offline crate set.)
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse a TOML document into dotted-path → value.
pub fn parse(input: &str) -> Result<BTreeMap<String, TomlValue>, TomlError> {
    let mut out = BTreeMap::new();
    let mut prefix = String::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError {
            line: lineno + 1,
            msg: msg.to_string(),
        };
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err("unterminated table header"))?
                .trim();
            if name.is_empty() || name.contains('[') {
                return Err(err("bad table name"));
            }
            prefix = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
        let full = if prefix.is_empty() {
            key.to_string()
        } else {
            format!("{prefix}.{key}")
        };
        if out.insert(full.clone(), value).is_some() {
            return Err(err(&format!("duplicate key '{full}'")));
        }
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside a quoted string starts a comment; backslash escapes
    // inside strings (\" \\ …) never toggle the string state
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_str => escaped = true,
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(unescape(inner)?));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim())?);
        }
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

/// Decode the basic-string escapes the writer in [`super::file`] emits
/// (`\"`, `\\`, `\n`, `\r`, `\t`); a bare `"` cannot reach here (the
/// escape-aware tokenizers treat it as the string terminator), and an
/// unknown or dangling escape is an error.
fn unescape(s: &str) -> Result<String, String> {
    if !s.contains('\\') {
        if s.contains('"') {
            return Err("unescaped quote inside string".into());
        }
        return Ok(s.to_string());
    }
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            if c == '"' {
                return Err("unescaped quote inside string".into());
            }
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('t') => out.push('\t'),
            Some(other) => return Err(format!("unsupported escape '\\{other}'")),
            None => return Err("dangling escape at end of string".into()),
        }
    }
    Ok(out)
}

/// Split an inline-array body on commas not nested in brackets/strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut escaped = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_str => escaped = true,
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_tables() {
        let doc = r#"
            # experiment
            name = "fig1L"
            [topology]
            n = 100
            frac = 0.1
            pull = true
        "#;
        let m = parse(doc).unwrap();
        assert_eq!(m["name"].as_str().unwrap(), "fig1L");
        assert_eq!(m["topology.n"].as_i64().unwrap(), 100);
        assert_eq!(m["topology.frac"].as_f64().unwrap(), 0.1);
        assert!(m["topology.pull"].as_bool().unwrap());
    }

    #[test]
    fn arrays() {
        let m = parse("grid = [5, 10, 15]\nnested = [[0, 0.5], [500, 0.1]]").unwrap();
        let g = m["grid"].as_array().unwrap();
        assert_eq!(g.len(), 3);
        assert_eq!(g[2].as_i64().unwrap(), 15);
        let n = m["nested"].as_array().unwrap();
        assert_eq!(n[1].as_array().unwrap()[1].as_f64().unwrap(), 0.1);
    }

    #[test]
    fn comments_and_underscores() {
        let m = parse("x = 1_000 # one thousand\ns = \"a # b\"").unwrap();
        assert_eq!(m["x"].as_i64().unwrap(), 1000);
        assert_eq!(m["s"].as_str().unwrap(), "a # b");
    }

    #[test]
    fn int_coerces_to_f64() {
        let m = parse("lr = 1").unwrap();
        assert_eq!(m["lr"].as_f64().unwrap(), 1.0);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("good = 1\nbad bad").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("x = ").unwrap_err();
        assert_eq!(e.line, 1);
        assert!(parse("[unterminated").is_err());
        assert!(parse("dup = 1\ndup = 2").is_err());
    }

    #[test]
    fn empty_array_and_strings() {
        let m = parse("a = []\nb = \"\"").unwrap();
        assert_eq!(m["a"].as_array().unwrap().len(), 0);
        assert_eq!(m["b"].as_str().unwrap(), "");
    }

    #[test]
    fn escaped_strings_round_trip() {
        // the escapes config::file::to_toml_str emits must parse back
        let m = parse(r#"name = "push \"quoted\"/weird\\end""#).unwrap();
        assert_eq!(m["name"].as_str().unwrap(), "push \"quoted\"/weird\\end");
        let m = parse(r#"s = "tab\there # not a comment""#).unwrap();
        assert_eq!(m["s"].as_str().unwrap(), "tab\there # not a comment");
        // an escaped quote must not end the string for the comment scanner
        let m = parse("x = \"a\\\"# still string\" # real comment").unwrap();
        assert_eq!(m["x"].as_str().unwrap(), "a\"# still string");
    }

    #[test]
    fn bad_escapes_rejected() {
        assert!(parse(r#"s = "bad \q escape""#).is_err());
        assert!(parse("s = \"dangling\\\"").is_err());
    }
}
