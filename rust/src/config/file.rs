//! TOML → [`ExperimentConfig`] schema mapping.
//!
//! Config files look like:
//!
//! ```toml
//! name = "my_run"
//! task = "mnistlike"          # mnistlike | cifarlike | femnistlike | tiny
//! engine = "hlo"              # hlo | native
//! threads = 4                 # round-engine workers (0 = all cores)
//! shards = 2                  # node-shard partitions (default 1)
//!
//! [nodes]
//! n = 100
//! byzantine = 10
//!
//! [topology]
//! kind = "epidemic"           # epidemic | fixed_graph
//! s = 15                      # epidemic fan-in (or edges = ... for graphs)
//!
//! [robustness]
//! rule = "nnm_cwtm"
//! attack = "alie"
//! bhat = 7                    # omit to run Algorithm 2
//!
//! [training]
//! rounds = 200
//! batch = 25
//! local_steps = 1
//! lr = [[0, 0.5], [500, 0.1]] # piecewise-constant (round, lr)
//! momentum = 0.9
//! weight_decay = 1e-4
//!
//! [data]
//! alpha = 1.0
//! samples_per_node = 128
//! test_samples = 512
//!
//! [wire]
//! compression = "none"        # none | f16 | q8 (row-block codec, see wire::codec)
//! ```

use std::collections::BTreeMap;

use super::toml::{parse, TomlValue};
use super::{
    AsyncCfg, Compression, EngineKind, ExperimentConfig, RecoveryCfg, RuleChoice, StalePolicyKind,
    StragglerKind, Topology, TransportKind,
};
use crate::aggregation::gossip::GossipRuleKind;
use crate::aggregation::RuleKind;
use crate::attacks::AttackKind;
use crate::data::TaskKind;

fn task_from_name(s: &str) -> Option<TaskKind> {
    Some(match s {
        "mnistlike" | "mnist" => TaskKind::MnistLike,
        "cifarlike" | "cifar" => TaskKind::CifarLike,
        "femnistlike" | "femnist" => TaskKind::FemnistLike,
        "tiny" => TaskKind::Tiny,
        _ => return None,
    })
}

pub(crate) type Doc = BTreeMap<String, TomlValue>;

fn get_usize(doc: &Doc, key: &str) -> Result<Option<usize>, String> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_i64()
            .filter(|&i| i >= 0)
            .map(|i| Some(i as usize))
            .ok_or_else(|| format!("'{key}' must be a non-negative integer")),
    }
}

fn get_f64(doc: &Doc, key: &str) -> Result<Option<f64>, String> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| format!("'{key}' must be a number")),
    }
}

fn get_str<'a>(doc: &'a Doc, key: &str) -> Result<Option<&'a str>, String> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(Some)
            .ok_or_else(|| format!("'{key}' must be a string")),
    }
}

fn get_bool(doc: &Doc, key: &str) -> Result<Option<bool>, String> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_bool()
            .map(Some)
            .ok_or_else(|| format!("'{key}' must be a boolean")),
    }
}

/// Parse a TOML document into a config (missing keys fall back to the
/// task's defaults).
pub fn from_toml_str(text: &str) -> Result<ExperimentConfig, String> {
    let doc = parse(text).map_err(|e| e.to_string())?;

    let task = match get_str(&doc, "task")? {
        Some(name) => task_from_name(name).ok_or_else(|| format!("unknown task '{name}'"))?,
        None => TaskKind::Tiny,
    };
    let mut cfg = ExperimentConfig::default_for(task);

    if let Some(name) = get_str(&doc, "name")? {
        cfg.name = name.to_string();
    }
    if let Some(arch) = get_str(&doc, "arch")? {
        cfg.arch = arch.to_string();
    }
    if let Some(engine) = get_str(&doc, "engine")? {
        cfg.engine =
            EngineKind::parse(engine).ok_or_else(|| format!("unknown engine '{engine}'"))?;
    }
    if let Some(dir) = get_str(&doc, "artifacts_dir")? {
        cfg.artifacts_dir = dir.to_string();
    }
    if let Some(seed) = get_usize(&doc, "seed")? {
        cfg.seed = seed as u64;
    }
    if let Some(threads) = get_usize(&doc, "threads")? {
        cfg.threads = threads;
    }
    if let Some(shards) = get_usize(&doc, "shards")? {
        cfg.shards = shards;
    }
    if let Some(procs) = get_usize(&doc, "procs")? {
        cfg.procs = procs;
    }
    if let Some(t) = get_str(&doc, "transport")? {
        cfg.transport =
            TransportKind::parse(t).ok_or_else(|| format!("unknown transport '{t}'"))?;
    }
    if let Some(dir) = get_str(&doc, "socket_dir")? {
        cfg.socket_dir = dir.to_string();
    }
    if let Some(p) = get_f64(&doc, "participation")? {
        cfg.participation = p;
    }
    if let Some(v) = get_bool(&doc, "virtual_nodes")? {
        cfg.virtual_nodes = v;
    }
    if let Some(s) = get_str(&doc, "wire.compression")? {
        cfg.compression = Compression::parse(s)
            .ok_or_else(|| format!("unknown compression '{s}' (none|f16|q8)"))?;
    }

    if let Some(n) = get_usize(&doc, "nodes.n")? {
        cfg.n = n;
    }
    if let Some(b) = get_usize(&doc, "nodes.byzantine")? {
        cfg.b = b;
    }

    let topo_kind = get_str(&doc, "topology.kind")?.unwrap_or("epidemic");
    match topo_kind {
        "epidemic" => {
            let s = get_usize(&doc, "topology.s")?.unwrap_or(match cfg.topology {
                Topology::Epidemic { s } => s,
                _ => 6,
            });
            cfg.topology = Topology::Epidemic { s };
        }
        "epidemic_push" | "push" => {
            let s = get_usize(&doc, "topology.s")?.unwrap_or(6);
            cfg.topology = Topology::EpidemicPush { s };
        }
        "fixed_graph" | "graph" => {
            let edges = match get_usize(&doc, "topology.edges")? {
                Some(e) => e,
                None => {
                    // paper default: same budget as epidemic, K = n*s/2
                    let s = get_usize(&doc, "topology.s")?
                        .ok_or("fixed_graph topology needs 'edges' or 's'")?;
                    cfg.n * s / 2
                }
            };
            cfg.topology = Topology::FixedGraph { edges };
            // default rule family must match
            cfg.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
        }
        other => return Err(format!("unknown topology '{other}'")),
    }

    if let Some(rule) = get_str(&doc, "robustness.rule")? {
        cfg.rule = if matches!(
            cfg.topology,
            Topology::Epidemic { .. } | Topology::EpidemicPush { .. }
        ) {
            RuleChoice::Epidemic(
                RuleKind::parse(rule).ok_or_else(|| format!("unknown rule '{rule}'"))?,
            )
        } else {
            RuleChoice::Gossip(
                GossipRuleKind::parse(rule)
                    .ok_or_else(|| format!("unknown gossip rule '{rule}'"))?,
            )
        };
    }
    if let Some(attack) = get_str(&doc, "robustness.attack")? {
        cfg.attack =
            AttackKind::parse(attack).ok_or_else(|| format!("unknown attack '{attack}'"))?;
    }
    cfg.bhat = get_usize(&doc, "robustness.bhat")?;

    if let Some(v) = get_usize(&doc, "training.rounds")? {
        cfg.rounds = v;
    }
    if let Some(v) = get_usize(&doc, "training.batch")? {
        cfg.batch = v;
    }
    if let Some(v) = get_usize(&doc, "training.local_steps")? {
        cfg.local_steps = v.max(1);
    }
    if let Some(v) = get_f64(&doc, "training.momentum")? {
        cfg.momentum = v as f32;
    }
    if let Some(v) = get_f64(&doc, "training.weight_decay")? {
        cfg.weight_decay = v as f32;
    }
    if let Some(v) = doc.get("training.lr") {
        cfg.lr_schedule = parse_lr(v)?;
    }

    if let Some(v) = get_f64(&doc, "data.alpha")? {
        cfg.alpha = v;
    }
    if let Some(v) = get_usize(&doc, "data.samples_per_node")? {
        cfg.samples_per_node = v;
    }
    if let Some(v) = get_usize(&doc, "data.test_samples")? {
        cfg.test_samples = v;
    }
    if let Some(v) = get_usize(&doc, "data.eval_every")? {
        cfg.eval_every = v.max(1);
    }

    async_from_doc(&doc, &mut cfg.asyn)?;
    recovery_from_doc(&doc, &mut cfg.recovery)?;

    cfg.validate()?;
    Ok(cfg)
}

/// Apply a `[recovery]` section onto `rec` (missing keys keep their
/// current value).
pub(crate) fn recovery_from_doc(doc: &Doc, rec: &mut RecoveryCfg) -> Result<(), String> {
    if let Some(s) = get_str(doc, "recovery.checkpoint_dir")? {
        rec.checkpoint_dir = s.to_string();
    }
    if let Some(v) = get_usize(doc, "recovery.checkpoint_every")? {
        rec.checkpoint_every = v;
    }
    if let Some(v) = get_usize(doc, "recovery.handshake_timeout_secs")? {
        rec.handshake_timeout_secs = v as u64;
    }
    if let Some(v) = get_usize(doc, "recovery.max_worker_restarts")? {
        rec.max_worker_restarts = v;
    }
    if let Some(v) = get_usize(doc, "recovery.retry_attempts")? {
        rec.retry_attempts = v;
    }
    if let Some(v) = get_usize(doc, "recovery.retry_backoff_ms")? {
        rec.retry_backoff_ms = v as u64;
    }
    Ok(())
}

/// Apply an `[async]` section onto `asyn` (missing keys keep their
/// current value). Shared with [`crate::testkit::scenario`], whose named
/// scenario configs speak the same schema.
pub(crate) fn async_from_doc(doc: &Doc, asyn: &mut AsyncCfg) -> Result<(), String> {
    if let Some(v) = get_usize(doc, "async.quorum")? {
        asyn.quorum = v;
    }
    if let Some(v) = get_f64(doc, "async.deadline")? {
        asyn.deadline = v;
    }
    if let Some(v) = get_usize(doc, "async.max_staleness")? {
        asyn.max_staleness = v;
    }
    if let Some(s) = get_str(doc, "async.stale_policy")? {
        asyn.stale_policy = StalePolicyKind::parse(s)
            .ok_or_else(|| format!("unknown stale policy '{s}' (carry|decay)"))?;
    }
    if let Some(v) = get_f64(doc, "async.stale_decay")? {
        asyn.stale_decay = v;
    }
    if let Some(s) = get_str(doc, "async.straggler")? {
        asyn.straggler = StragglerKind::parse(s)
            .ok_or_else(|| format!("unknown straggler kind '{s}' (constant|two_point|lognormal)"))?;
    }
    if let Some(v) = get_f64(doc, "async.base_latency")? {
        asyn.base_latency = v;
    }
    if let Some(v) = get_f64(doc, "async.slow_prob")? {
        asyn.slow_prob = v;
    }
    if let Some(v) = get_f64(doc, "async.slow_latency")? {
        asyn.slow_latency = v;
    }
    if let Some(v) = get_f64(doc, "async.sigma")? {
        asyn.sigma = v;
    }
    if let Some(v) = get_f64(doc, "async.crash_prob")? {
        asyn.crash_prob = v;
    }
    if let Some(v) = get_usize(doc, "async.down_rounds")? {
        asyn.down_rounds = v;
    }
    if let Some(v) = get_usize(doc, "async.part_from")? {
        asyn.part_from = v;
    }
    if let Some(v) = get_usize(doc, "async.part_to")? {
        asyn.part_to = v;
    }
    if let Some(v) = get_usize(doc, "async.part_nodes")? {
        asyn.part_nodes = v;
    }
    Ok(())
}

/// `lr = 0.5` or `lr = [[0, 0.5], [500, 0.1]]`.
fn parse_lr(v: &TomlValue) -> Result<Vec<(usize, f32)>, String> {
    if let Some(x) = v.as_f64() {
        return Ok(vec![(0, x as f32)]);
    }
    let arr = v.as_array().ok_or("'training.lr' must be number or array")?;
    let mut out = Vec::new();
    for item in arr {
        let pair = item
            .as_array()
            .filter(|p| p.len() == 2)
            .ok_or("lr schedule entries must be [round, lr] pairs")?;
        let round = pair[0]
            .as_i64()
            .filter(|&r| r >= 0)
            .ok_or("lr schedule round must be a non-negative integer")? as usize;
        let lr = pair[1].as_f64().ok_or("lr value must be a number")? as f32;
        out.push((round, lr));
    }
    if out.is_empty() {
        return Err("empty lr schedule".into());
    }
    Ok(out)
}

/// Load a config from a file path.
pub fn load(path: &str) -> Result<ExperimentConfig, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("cannot read '{path}': {e}"))?;
    from_toml_str(&text)
}

// ---------------------------------------------------------------------------
// Serialization (the coordinator ships the exact config to every
// `rpel shard-worker` over the wire; `from_toml_str(to_toml_str(cfg))`
// must reproduce `cfg` field-for-field)
// ---------------------------------------------------------------------------

/// Escape a string for a double-quoted TOML value.
fn toml_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            _ => out.push(c),
        }
    }
    out
}

/// Shortest decimal that round-trips (Rust's float `Display` guarantees
/// this per type; for f32 the reparse-via-f64 double rounding is exact
/// because the shortest decimal uniquely identifies the f32 and f64 has
/// surplus precision); a trailing `.0` is appended for integral values
/// so the TOML parser yields a float, though `as_f64` accepts integers
/// anyway.
fn fmt_num<T: std::fmt::Display>(v: T) -> String {
    let s = format!("{v}");
    // non-finite values ("inf"/"-inf"/"NaN") must not grow a ".0" — the
    // parser accepts the bare spellings (config validation rejects them
    // anyway, so they never reach a shard worker)
    if s.contains('.') || s.contains('e') || s.contains('E') || s.contains("inf") || s.contains("NaN")
    {
        s
    } else {
        format!("{s}.0")
    }
}

fn fmt_float(v: f64) -> String {
    fmt_num(v)
}

fn fmt_f32(v: f32) -> String {
    fmt_num(v)
}

/// Serialize a config to the TOML schema [`from_toml_str`] reads. Every
/// semantics-bearing field is emitted, so parsing the output reproduces
/// the config exactly (floats round-trip through shortest-decimal
/// printing, which uniquely identifies the original f32/f64 value).
pub fn to_toml_str(cfg: &ExperimentConfig) -> String {
    let mut out = String::new();
    out.push_str(&format!("name = \"{}\"\n", toml_escape(&cfg.name)));
    out.push_str(&format!("task = \"{}\"\n", cfg.task.name()));
    out.push_str(&format!("arch = \"{}\"\n", toml_escape(&cfg.arch)));
    out.push_str(&format!("engine = \"{}\"\n", cfg.engine.name()));
    out.push_str(&format!(
        "artifacts_dir = \"{}\"\n",
        toml_escape(&cfg.artifacts_dir)
    ));
    out.push_str(&format!("seed = {}\n", cfg.seed));
    out.push_str(&format!("threads = {}\n", cfg.threads));
    out.push_str(&format!("shards = {}\n", cfg.shards));
    out.push_str(&format!("procs = {}\n", cfg.procs));
    out.push_str(&format!("transport = \"{}\"\n", cfg.transport.name()));
    out.push_str(&format!(
        "socket_dir = \"{}\"\n",
        toml_escape(&cfg.socket_dir)
    ));
    // the sparse-engine knobs follow the [async] convention: emitted only
    // off-default, so a dense full-participation config serializes
    // byte-identically to what it did before the sparse engine existed
    // (worker Init frames included)
    if cfg.participation != 1.0 {
        out.push_str(&format!(
            "participation = {}\n",
            fmt_float(cfg.participation)
        ));
    }
    if cfg.virtual_nodes {
        out.push_str("virtual_nodes = true\n");
    }

    out.push_str("\n[nodes]\n");
    out.push_str(&format!("n = {}\n", cfg.n));
    out.push_str(&format!("byzantine = {}\n", cfg.b));

    out.push_str("\n[topology]\n");
    match cfg.topology {
        Topology::Epidemic { s } => {
            out.push_str("kind = \"epidemic\"\n");
            out.push_str(&format!("s = {s}\n"));
        }
        Topology::EpidemicPush { s } => {
            out.push_str("kind = \"epidemic_push\"\n");
            out.push_str(&format!("s = {s}\n"));
        }
        Topology::FixedGraph { edges } => {
            out.push_str("kind = \"fixed_graph\"\n");
            out.push_str(&format!("edges = {edges}\n"));
        }
    }

    out.push_str("\n[robustness]\n");
    out.push_str(&format!("rule = \"{}\"\n", cfg.rule.name()));
    out.push_str(&format!("attack = \"{}\"\n", cfg.attack.name()));
    if let Some(bhat) = cfg.bhat {
        out.push_str(&format!("bhat = {bhat}\n"));
    }

    out.push_str("\n[training]\n");
    out.push_str(&format!("rounds = {}\n", cfg.rounds));
    out.push_str(&format!("batch = {}\n", cfg.batch));
    out.push_str(&format!("local_steps = {}\n", cfg.local_steps));
    let lr: Vec<String> = cfg
        .lr_schedule
        .iter()
        .map(|&(round, v)| format!("[{round}, {}]", fmt_f32(v)))
        .collect();
    out.push_str(&format!("lr = [{}]\n", lr.join(", ")));
    out.push_str(&format!("momentum = {}\n", fmt_f32(cfg.momentum)));
    out.push_str(&format!("weight_decay = {}\n", fmt_f32(cfg.weight_decay)));

    out.push_str("\n[data]\n");
    out.push_str(&format!("alpha = {}\n", fmt_float(cfg.alpha)));
    out.push_str(&format!("samples_per_node = {}\n", cfg.samples_per_node));
    out.push_str(&format!("test_samples = {}\n", cfg.test_samples));
    out.push_str(&format!("eval_every = {}\n", cfg.eval_every));

    // [wire] follows the [async]/sparse convention: emitted only
    // off-default, so a compression = none config serializes
    // byte-identically to the pre-codec schema (worker Init frames
    // included — that byte-equality is an acceptance criterion)
    if !cfg.compression.is_none() {
        out.push_str("\n[wire]\n");
        out.push_str(&format!("compression = \"{}\"\n", cfg.compression.name()));
    }

    // [async] is emitted only when some knob moved off the default: a
    // synchronous config serializes byte-identically to what it did
    // before asynchrony existed (worker Init frames included)
    if cfg.asyn != AsyncCfg::default() {
        async_to_toml(&mut out, &cfg.asyn);
    }

    // [recovery] likewise: an all-default config keeps the worker Init
    // frame byte-identical to the pre-recovery schema
    if cfg.recovery != RecoveryCfg::default() {
        recovery_to_toml(&mut out, &cfg.recovery);
    }
    out
}

/// Append the `[recovery]` section. Every field is emitted so a reparse
/// reproduces the value exactly.
pub(crate) fn recovery_to_toml(out: &mut String, rec: &RecoveryCfg) {
    out.push_str("\n[recovery]\n");
    out.push_str(&format!(
        "checkpoint_dir = \"{}\"\n",
        toml_escape(&rec.checkpoint_dir)
    ));
    out.push_str(&format!("checkpoint_every = {}\n", rec.checkpoint_every));
    out.push_str(&format!(
        "handshake_timeout_secs = {}\n",
        rec.handshake_timeout_secs
    ));
    out.push_str(&format!(
        "max_worker_restarts = {}\n",
        rec.max_worker_restarts
    ));
    out.push_str(&format!("retry_attempts = {}\n", rec.retry_attempts));
    out.push_str(&format!("retry_backoff_ms = {}\n", rec.retry_backoff_ms));
}

/// Append the `[async]` section for `asyn`. Every field is emitted so a
/// reparse reproduces the value exactly; shared with
/// [`crate::testkit::scenario`].
pub(crate) fn async_to_toml(out: &mut String, asyn: &AsyncCfg) {
    out.push_str("\n[async]\n");
    out.push_str(&format!("quorum = {}\n", asyn.quorum));
    out.push_str(&format!("deadline = {}\n", fmt_float(asyn.deadline)));
    out.push_str(&format!("max_staleness = {}\n", asyn.max_staleness));
    out.push_str(&format!("stale_policy = \"{}\"\n", asyn.stale_policy.name()));
    out.push_str(&format!("stale_decay = {}\n", fmt_float(asyn.stale_decay)));
    out.push_str(&format!("straggler = \"{}\"\n", asyn.straggler.name()));
    out.push_str(&format!("base_latency = {}\n", fmt_float(asyn.base_latency)));
    out.push_str(&format!("slow_prob = {}\n", fmt_float(asyn.slow_prob)));
    out.push_str(&format!("slow_latency = {}\n", fmt_float(asyn.slow_latency)));
    out.push_str(&format!("sigma = {}\n", fmt_float(asyn.sigma)));
    out.push_str(&format!("crash_prob = {}\n", fmt_float(asyn.crash_prob)));
    out.push_str(&format!("down_rounds = {}\n", asyn.down_rounds));
    out.push_str(&format!("part_from = {}\n", asyn.part_from));
    out.push_str(&format!("part_to = {}\n", asyn.part_to));
    out.push_str(&format!("part_nodes = {}\n", asyn.part_nodes));
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"
        name = "fig1L"
        task = "mnistlike"
        engine = "native"
        seed = 3
        [nodes]
        n = 100
        byzantine = 10
        [topology]
        kind = "epidemic"
        s = 15
        [robustness]
        rule = "nnm_cwtm"
        attack = "alie"
        bhat = 7
        [training]
        rounds = 200
        batch = 25
        lr = [[0, 0.5]]
        momentum = 0.9
        weight_decay = 1e-4
        [data]
        alpha = 1.0
        samples_per_node = 100
    "#;

    #[test]
    fn full_document_parses() {
        let cfg = from_toml_str(FULL).unwrap();
        assert_eq!(cfg.name, "fig1L");
        assert_eq!(cfg.n, 100);
        assert_eq!(cfg.b, 10);
        assert_eq!(cfg.topology, Topology::Epidemic { s: 15 });
        assert_eq!(cfg.bhat, Some(7));
        assert_eq!(cfg.attack, AttackKind::Alie);
        assert_eq!(cfg.rounds, 200);
        assert_eq!(cfg.seed, 3);
        cfg.validate().unwrap();
    }

    #[test]
    fn minimal_document_uses_defaults() {
        let cfg = from_toml_str("task = \"tiny\"").unwrap();
        assert_eq!(cfg.task, TaskKind::Tiny);
        cfg.validate().unwrap();
    }

    #[test]
    fn fixed_graph_via_s_budget() {
        let cfg = from_toml_str(
            r#"
            task = "mnistlike"
            [nodes]
            n = 30
            byzantine = 6
            [topology]
            kind = "fixed_graph"
            s = 10
            [robustness]
            rule = "cs_plus"
            bhat = 4
            "#,
        )
        .unwrap();
        assert_eq!(cfg.topology, Topology::FixedGraph { edges: 150 });
        assert!(matches!(
            cfg.rule,
            RuleChoice::Gossip(GossipRuleKind::CsPlus)
        ));
    }

    #[test]
    fn threads_parsed_with_auto_default() {
        let cfg = from_toml_str("task = \"tiny\"\nthreads = 4").unwrap();
        assert_eq!(cfg.threads, 4);
        let cfg = from_toml_str("task = \"tiny\"").unwrap();
        assert_eq!(cfg.threads, 0, "default must be auto (all cores)");
    }

    #[test]
    fn shards_parsed_with_serial_default() {
        let cfg = from_toml_str("task = \"tiny\"\nshards = 3").unwrap();
        assert_eq!(cfg.shards, 3);
        let cfg = from_toml_str("task = \"tiny\"").unwrap();
        assert_eq!(cfg.shards, 1, "default must be the single-shard engine");
    }

    #[test]
    fn scalar_lr_accepted() {
        let cfg = from_toml_str("task = \"tiny\"\n[training]\nlr = 0.25").unwrap();
        assert_eq!(cfg.lr_schedule, vec![(0, 0.25)]);
    }

    #[test]
    fn staircase_lr_parsed() {
        let cfg = from_toml_str(
            "task = \"tiny\"\n[training]\nlr = [[0, 0.5], [500, 0.1], [1000, 0.02]]",
        )
        .unwrap();
        assert_eq!(cfg.lr_schedule.len(), 3);
        assert_eq!(cfg.lr_at(700), 0.1);
    }

    #[test]
    fn procs_parsed_with_in_process_default() {
        let cfg = from_toml_str("task = \"tiny\"\nprocs = 2").unwrap();
        assert_eq!(cfg.procs, 2);
        let cfg = from_toml_str("task = \"tiny\"").unwrap();
        assert_eq!(cfg.procs, 1, "default must be the in-process engine");
    }

    #[test]
    fn transport_parsed_with_pipe_default() {
        let cfg = from_toml_str("task = \"tiny\"\ntransport = \"socket\"").unwrap();
        assert_eq!(cfg.transport, TransportKind::Socket);
        let cfg = from_toml_str("task = \"tiny\"\ntransport = \"tcp\"").unwrap();
        assert_eq!(cfg.transport, TransportKind::Tcp);
        let cfg = from_toml_str("task = \"tiny\"").unwrap();
        assert_eq!(cfg.transport, TransportKind::Pipe, "default must be pipes");
        assert!(from_toml_str("task = \"tiny\"\ntransport = \"telegraph\"").is_err());
    }

    #[test]
    fn async_section_parsed_with_sync_default() {
        let cfg = from_toml_str(
            r#"
            task = "tiny"
            [async]
            quorum = 9
            deadline = 8.0
            max_staleness = 2
            stale_policy = "decay"
            stale_decay = 0.5
            straggler = "two_point"
            slow_prob = 0.2
            slow_latency = 5.0
            "#,
        )
        .unwrap();
        assert!(cfg.asyn.is_enabled());
        assert_eq!(cfg.asyn.quorum, 9);
        assert_eq!(cfg.asyn.deadline, 8.0);
        assert_eq!(cfg.asyn.max_staleness, 2);
        assert_eq!(cfg.asyn.stale_policy, crate::config::StalePolicyKind::Decay);
        assert_eq!(cfg.asyn.straggler, crate::config::StragglerKind::TwoPoint);
        assert_eq!(cfg.asyn.slow_prob, 0.2);

        // no [async] section → the synchronous engine, and the shipped
        // TOML must not grow an [async] section (worker Init frames for
        // sync runs stay byte-identical to the pre-async wire format)
        let sync = from_toml_str("task = \"tiny\"").unwrap();
        assert!(!sync.asyn.is_enabled());
        assert!(!to_toml_str(&sync).contains("[async]"));

        assert!(
            from_toml_str("task = \"tiny\"\n[async]\nstale_policy = \"drop\"").is_err(),
            "unknown stale policy must be rejected"
        );
        assert!(
            from_toml_str("task = \"tiny\"\n[async]\nquorum = 99").is_err(),
            "quorum past the honest count must be rejected"
        );
    }

    #[test]
    fn sparse_keys_parsed_with_dense_defaults() {
        let cfg =
            from_toml_str("task = \"tiny\"\nparticipation = 0.5\nvirtual_nodes = true").unwrap();
        assert_eq!(cfg.participation, 0.5);
        assert!(cfg.virtual_nodes);

        // defaults are the dense full-participation engine, and a default
        // config must not grow the sparse keys on serialization
        let dense = from_toml_str("task = \"tiny\"").unwrap();
        assert_eq!(dense.participation, 1.0);
        assert!(!dense.virtual_nodes);
        let text = to_toml_str(&dense);
        assert!(!text.contains("participation"));
        assert!(!text.contains("virtual_nodes"));

        assert!(
            from_toml_str("task = \"tiny\"\nparticipation = 0.0").is_err(),
            "participation outside (0, 1] must be rejected"
        );
        assert!(
            from_toml_str("task = \"tiny\"\nvirtual_nodes = 1").is_err(),
            "virtual_nodes must be a boolean"
        );
    }

    #[test]
    fn wire_compression_parsed_with_none_default() {
        let cfg = from_toml_str("task = \"tiny\"\n[wire]\ncompression = \"q8\"").unwrap();
        assert_eq!(cfg.compression, Compression::Q8);
        let cfg = from_toml_str("task = \"tiny\"\n[wire]\ncompression = \"f16\"").unwrap();
        assert_eq!(cfg.compression, Compression::F16);

        // default is none, and a none config must not grow a [wire]
        // section on serialization (Init frames stay byte-identical to
        // the pre-codec schema)
        let plain = from_toml_str("task = \"tiny\"").unwrap();
        assert_eq!(plain.compression, Compression::None);
        assert!(!to_toml_str(&plain).contains("[wire]"));

        assert!(
            from_toml_str("task = \"tiny\"\n[wire]\ncompression = \"gzip\"").is_err(),
            "unknown compression must be rejected"
        );
    }

    #[test]
    fn recovery_keys_parsed_with_quiet_default() {
        let cfg = from_toml_str(
            "task = \"tiny\"\n[recovery]\ncheckpoint_dir = \"ck\"\ncheckpoint_every = 3\n\
             handshake_timeout_secs = 5\nmax_worker_restarts = 0\nretry_attempts = 1\n\
             retry_backoff_ms = 0",
        )
        .unwrap();
        assert_eq!(cfg.recovery.checkpoint_dir, "ck");
        assert_eq!(cfg.recovery.checkpoint_every, 3);
        assert_eq!(cfg.recovery.handshake_timeout_secs, 5);
        assert_eq!(cfg.recovery.max_worker_restarts, 0);
        assert_eq!(cfg.recovery.retry_attempts, 1);
        assert_eq!(cfg.recovery.retry_backoff_ms, 0);

        // an all-default config must not grow a [recovery] section on
        // serialization (worker Init frames stay byte-identical to the
        // pre-recovery schema)
        let plain = from_toml_str("task = \"tiny\"").unwrap();
        assert_eq!(plain.recovery, crate::config::RecoveryCfg::default());
        assert!(!to_toml_str(&plain).contains("[recovery]"));

        // validation runs on parsed values: a zero handshake deadline is
        // rejected with the exact bound
        let err = from_toml_str("task = \"tiny\"\n[recovery]\nhandshake_timeout_secs = 0")
            .unwrap_err();
        assert_eq!(err, "recovery.handshake_timeout_secs must be >= 1, got 0");
    }

    /// `to_toml_str` is what the coordinator ships to every shard-worker
    /// process: a parse of the output must reproduce the config
    /// field-for-field, or workers would silently build a different world.
    #[test]
    fn toml_serialization_round_trips_exactly() {
        use crate::config::presets;

        let mut push_cfg = crate::config::ExperimentConfig::default_for(TaskKind::Tiny);
        push_cfg.name = "push \"quoted\"/weird".into();
        push_cfg.topology = Topology::EpidemicPush { s: 4 };
        push_cfg.b = 2;
        push_cfg.n = 11;
        push_cfg.bhat = None;
        push_cfg.attack = AttackKind::Dos;
        push_cfg.lr_schedule = vec![(0, 0.5), (500, 0.1), (1000, 0.02)];
        push_cfg.weight_decay = 1e-4;
        push_cfg.threads = 3;
        push_cfg.shards = 2;
        push_cfg.procs = 2;
        push_cfg.transport = TransportKind::Socket;
        push_cfg.socket_dir = "/tmp/rpel \"sock\"".into();

        let mut graph_cfg = crate::config::ExperimentConfig::default_for(TaskKind::MnistLike);
        graph_cfg.topology = Topology::FixedGraph { edges: 60 };
        graph_cfg.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
        graph_cfg.alpha = 0.3;
        graph_cfg.seed = 12345;

        let mut async_cfg = crate::config::ExperimentConfig::default_for(TaskKind::Tiny);
        async_cfg.asyn.quorum = 7;
        async_cfg.asyn.deadline = 12.5;
        async_cfg.asyn.max_staleness = 3;
        async_cfg.asyn.stale_policy = crate::config::StalePolicyKind::Decay;
        async_cfg.asyn.stale_decay = 0.25;
        async_cfg.asyn.straggler = crate::config::StragglerKind::LogNormal;
        async_cfg.asyn.base_latency = 2.0;
        async_cfg.asyn.sigma = 0.75;
        async_cfg.asyn.crash_prob = 0.05;
        async_cfg.asyn.down_rounds = 4;
        async_cfg.asyn.part_from = 3;
        async_cfg.asyn.part_to = 6;
        async_cfg.asyn.part_nodes = 2;

        let mut sparse_cfg = crate::config::ExperimentConfig::default_for(TaskKind::Tiny);
        sparse_cfg.participation = 0.25;
        sparse_cfg.virtual_nodes = true;
        sparse_cfg.asyn.quorum = 7;
        sparse_cfg.asyn.max_staleness = 2;

        let mut wire_cfg = crate::config::ExperimentConfig::default_for(TaskKind::Tiny);
        wire_cfg.compression = Compression::Q8;
        wire_cfg.procs = 2;
        wire_cfg.transport = TransportKind::Socket;

        let mut recovery_cfg = crate::config::ExperimentConfig::default_for(TaskKind::Tiny);
        recovery_cfg.recovery.checkpoint_dir = "/tmp/rpel \"ckpt\"".into();
        recovery_cfg.recovery.checkpoint_every = 5;
        recovery_cfg.recovery.handshake_timeout_secs = 7;
        recovery_cfg.recovery.max_worker_restarts = 1;
        recovery_cfg.recovery.retry_attempts = 4;
        recovery_cfg.recovery.retry_backoff_ms = 25;

        for cfg in [
            presets::quickstart_config(),
            from_toml_str(FULL).unwrap(),
            push_cfg,
            graph_cfg,
            async_cfg,
            sparse_cfg,
            wire_cfg,
            recovery_cfg,
        ] {
            let text = to_toml_str(&cfg);
            let back = from_toml_str(&text)
                .unwrap_or_else(|e| panic!("reparse failed: {e}\n---\n{text}"));
            assert_eq!(back, cfg, "round-trip mismatch for:\n{text}");
        }
    }

    #[test]
    fn float_formatting_keeps_integral_values_parseable() {
        assert_eq!(fmt_float(1.0), "1.0");
        assert_eq!(fmt_float(0.3), "0.3");
        assert_eq!(fmt_f32(0.9), "0.9");
        assert_eq!(fmt_f32(1e-4), "0.0001");
        assert_eq!(fmt_f32(2.0), "2.0");
        // non-finite values must not grow a ".0" suffix ("inf.0" would
        // not parse); validation keeps them out of real configs
        assert_eq!(fmt_f32(f32::INFINITY), "inf");
        assert_eq!(fmt_float(f64::NEG_INFINITY), "-inf");
        assert_eq!(fmt_float(f64::NAN), "NaN");
    }

    #[test]
    fn bad_values_rejected() {
        assert!(from_toml_str("task = \"nope\"").is_err());
        assert!(from_toml_str("task = \"tiny\"\n[robustness]\nattack = \"x\"").is_err());
        assert!(from_toml_str("task = \"tiny\"\n[topology]\nkind = \"ring\"").is_err());
        // validation: byzantine majority
        assert!(from_toml_str("task = \"tiny\"\n[nodes]\nn = 4\nbyzantine = 2").is_err());
    }
}
