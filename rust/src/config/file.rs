//! TOML → [`ExperimentConfig`] schema mapping.
//!
//! Config files look like:
//!
//! ```toml
//! name = "my_run"
//! task = "mnistlike"          # mnistlike | cifarlike | femnistlike | tiny
//! engine = "hlo"              # hlo | native
//! threads = 4                 # round-engine workers (0 = all cores)
//! shards = 2                  # node-shard partitions (default 1)
//!
//! [nodes]
//! n = 100
//! byzantine = 10
//!
//! [topology]
//! kind = "epidemic"           # epidemic | fixed_graph
//! s = 15                      # epidemic fan-in (or edges = ... for graphs)
//!
//! [robustness]
//! rule = "nnm_cwtm"
//! attack = "alie"
//! bhat = 7                    # omit to run Algorithm 2
//!
//! [training]
//! rounds = 200
//! batch = 25
//! local_steps = 1
//! lr = [[0, 0.5], [500, 0.1]] # piecewise-constant (round, lr)
//! momentum = 0.9
//! weight_decay = 1e-4
//!
//! [data]
//! alpha = 1.0
//! samples_per_node = 128
//! test_samples = 512
//! ```

use std::collections::BTreeMap;

use super::toml::{parse, TomlValue};
use super::{EngineKind, ExperimentConfig, RuleChoice, Topology};
use crate::aggregation::gossip::GossipRuleKind;
use crate::aggregation::RuleKind;
use crate::attacks::AttackKind;
use crate::data::TaskKind;

fn task_from_name(s: &str) -> Option<TaskKind> {
    Some(match s {
        "mnistlike" | "mnist" => TaskKind::MnistLike,
        "cifarlike" | "cifar" => TaskKind::CifarLike,
        "femnistlike" | "femnist" => TaskKind::FemnistLike,
        "tiny" => TaskKind::Tiny,
        _ => return None,
    })
}

type Doc = BTreeMap<String, TomlValue>;

fn get_usize(doc: &Doc, key: &str) -> Result<Option<usize>, String> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_i64()
            .filter(|&i| i >= 0)
            .map(|i| Some(i as usize))
            .ok_or_else(|| format!("'{key}' must be a non-negative integer")),
    }
}

fn get_f64(doc: &Doc, key: &str) -> Result<Option<f64>, String> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| format!("'{key}' must be a number")),
    }
}

fn get_str<'a>(doc: &'a Doc, key: &str) -> Result<Option<&'a str>, String> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(Some)
            .ok_or_else(|| format!("'{key}' must be a string")),
    }
}

/// Parse a TOML document into a config (missing keys fall back to the
/// task's defaults).
pub fn from_toml_str(text: &str) -> Result<ExperimentConfig, String> {
    let doc = parse(text).map_err(|e| e.to_string())?;

    let task = match get_str(&doc, "task")? {
        Some(name) => task_from_name(name).ok_or_else(|| format!("unknown task '{name}'"))?,
        None => TaskKind::Tiny,
    };
    let mut cfg = ExperimentConfig::default_for(task);

    if let Some(name) = get_str(&doc, "name")? {
        cfg.name = name.to_string();
    }
    if let Some(arch) = get_str(&doc, "arch")? {
        cfg.arch = arch.to_string();
    }
    if let Some(engine) = get_str(&doc, "engine")? {
        cfg.engine =
            EngineKind::parse(engine).ok_or_else(|| format!("unknown engine '{engine}'"))?;
    }
    if let Some(dir) = get_str(&doc, "artifacts_dir")? {
        cfg.artifacts_dir = dir.to_string();
    }
    if let Some(seed) = get_usize(&doc, "seed")? {
        cfg.seed = seed as u64;
    }
    if let Some(threads) = get_usize(&doc, "threads")? {
        cfg.threads = threads;
    }
    if let Some(shards) = get_usize(&doc, "shards")? {
        cfg.shards = shards;
    }

    if let Some(n) = get_usize(&doc, "nodes.n")? {
        cfg.n = n;
    }
    if let Some(b) = get_usize(&doc, "nodes.byzantine")? {
        cfg.b = b;
    }

    let topo_kind = get_str(&doc, "topology.kind")?.unwrap_or("epidemic");
    match topo_kind {
        "epidemic" => {
            let s = get_usize(&doc, "topology.s")?.unwrap_or(match cfg.topology {
                Topology::Epidemic { s } => s,
                _ => 6,
            });
            cfg.topology = Topology::Epidemic { s };
        }
        "epidemic_push" | "push" => {
            let s = get_usize(&doc, "topology.s")?.unwrap_or(6);
            cfg.topology = Topology::EpidemicPush { s };
        }
        "fixed_graph" | "graph" => {
            let edges = match get_usize(&doc, "topology.edges")? {
                Some(e) => e,
                None => {
                    // paper default: same budget as epidemic, K = n*s/2
                    let s = get_usize(&doc, "topology.s")?
                        .ok_or("fixed_graph topology needs 'edges' or 's'")?;
                    cfg.n * s / 2
                }
            };
            cfg.topology = Topology::FixedGraph { edges };
            // default rule family must match
            cfg.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
        }
        other => return Err(format!("unknown topology '{other}'")),
    }

    if let Some(rule) = get_str(&doc, "robustness.rule")? {
        cfg.rule = if matches!(cfg.topology, Topology::Epidemic { .. }) {
            RuleChoice::Epidemic(
                RuleKind::parse(rule).ok_or_else(|| format!("unknown rule '{rule}'"))?,
            )
        } else {
            RuleChoice::Gossip(
                GossipRuleKind::parse(rule)
                    .ok_or_else(|| format!("unknown gossip rule '{rule}'"))?,
            )
        };
    }
    if let Some(attack) = get_str(&doc, "robustness.attack")? {
        cfg.attack =
            AttackKind::parse(attack).ok_or_else(|| format!("unknown attack '{attack}'"))?;
    }
    cfg.bhat = get_usize(&doc, "robustness.bhat")?;

    if let Some(v) = get_usize(&doc, "training.rounds")? {
        cfg.rounds = v;
    }
    if let Some(v) = get_usize(&doc, "training.batch")? {
        cfg.batch = v;
    }
    if let Some(v) = get_usize(&doc, "training.local_steps")? {
        cfg.local_steps = v.max(1);
    }
    if let Some(v) = get_f64(&doc, "training.momentum")? {
        cfg.momentum = v as f32;
    }
    if let Some(v) = get_f64(&doc, "training.weight_decay")? {
        cfg.weight_decay = v as f32;
    }
    if let Some(v) = doc.get("training.lr") {
        cfg.lr_schedule = parse_lr(v)?;
    }

    if let Some(v) = get_f64(&doc, "data.alpha")? {
        cfg.alpha = v;
    }
    if let Some(v) = get_usize(&doc, "data.samples_per_node")? {
        cfg.samples_per_node = v;
    }
    if let Some(v) = get_usize(&doc, "data.test_samples")? {
        cfg.test_samples = v;
    }
    if let Some(v) = get_usize(&doc, "data.eval_every")? {
        cfg.eval_every = v.max(1);
    }

    cfg.validate()?;
    Ok(cfg)
}

/// `lr = 0.5` or `lr = [[0, 0.5], [500, 0.1]]`.
fn parse_lr(v: &TomlValue) -> Result<Vec<(usize, f32)>, String> {
    if let Some(x) = v.as_f64() {
        return Ok(vec![(0, x as f32)]);
    }
    let arr = v.as_array().ok_or("'training.lr' must be number or array")?;
    let mut out = Vec::new();
    for item in arr {
        let pair = item
            .as_array()
            .filter(|p| p.len() == 2)
            .ok_or("lr schedule entries must be [round, lr] pairs")?;
        let round = pair[0]
            .as_i64()
            .filter(|&r| r >= 0)
            .ok_or("lr schedule round must be a non-negative integer")? as usize;
        let lr = pair[1].as_f64().ok_or("lr value must be a number")? as f32;
        out.push((round, lr));
    }
    if out.is_empty() {
        return Err("empty lr schedule".into());
    }
    Ok(out)
}

/// Load a config from a file path.
pub fn load(path: &str) -> Result<ExperimentConfig, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("cannot read '{path}': {e}"))?;
    from_toml_str(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"
        name = "fig1L"
        task = "mnistlike"
        engine = "native"
        seed = 3
        [nodes]
        n = 100
        byzantine = 10
        [topology]
        kind = "epidemic"
        s = 15
        [robustness]
        rule = "nnm_cwtm"
        attack = "alie"
        bhat = 7
        [training]
        rounds = 200
        batch = 25
        lr = [[0, 0.5]]
        momentum = 0.9
        weight_decay = 1e-4
        [data]
        alpha = 1.0
        samples_per_node = 100
    "#;

    #[test]
    fn full_document_parses() {
        let cfg = from_toml_str(FULL).unwrap();
        assert_eq!(cfg.name, "fig1L");
        assert_eq!(cfg.n, 100);
        assert_eq!(cfg.b, 10);
        assert_eq!(cfg.topology, Topology::Epidemic { s: 15 });
        assert_eq!(cfg.bhat, Some(7));
        assert_eq!(cfg.attack, AttackKind::Alie);
        assert_eq!(cfg.rounds, 200);
        assert_eq!(cfg.seed, 3);
        cfg.validate().unwrap();
    }

    #[test]
    fn minimal_document_uses_defaults() {
        let cfg = from_toml_str("task = \"tiny\"").unwrap();
        assert_eq!(cfg.task, TaskKind::Tiny);
        cfg.validate().unwrap();
    }

    #[test]
    fn fixed_graph_via_s_budget() {
        let cfg = from_toml_str(
            r#"
            task = "mnistlike"
            [nodes]
            n = 30
            byzantine = 6
            [topology]
            kind = "fixed_graph"
            s = 10
            [robustness]
            rule = "cs_plus"
            bhat = 4
            "#,
        )
        .unwrap();
        assert_eq!(cfg.topology, Topology::FixedGraph { edges: 150 });
        assert!(matches!(
            cfg.rule,
            RuleChoice::Gossip(GossipRuleKind::CsPlus)
        ));
    }

    #[test]
    fn threads_parsed_with_auto_default() {
        let cfg = from_toml_str("task = \"tiny\"\nthreads = 4").unwrap();
        assert_eq!(cfg.threads, 4);
        let cfg = from_toml_str("task = \"tiny\"").unwrap();
        assert_eq!(cfg.threads, 0, "default must be auto (all cores)");
    }

    #[test]
    fn shards_parsed_with_serial_default() {
        let cfg = from_toml_str("task = \"tiny\"\nshards = 3").unwrap();
        assert_eq!(cfg.shards, 3);
        let cfg = from_toml_str("task = \"tiny\"").unwrap();
        assert_eq!(cfg.shards, 1, "default must be the single-shard engine");
    }

    #[test]
    fn scalar_lr_accepted() {
        let cfg = from_toml_str("task = \"tiny\"\n[training]\nlr = 0.25").unwrap();
        assert_eq!(cfg.lr_schedule, vec![(0, 0.25)]);
    }

    #[test]
    fn staircase_lr_parsed() {
        let cfg = from_toml_str(
            "task = \"tiny\"\n[training]\nlr = [[0, 0.5], [500, 0.1], [1000, 0.02]]",
        )
        .unwrap();
        assert_eq!(cfg.lr_schedule.len(), 3);
        assert_eq!(cfg.lr_at(700), 0.1);
    }

    #[test]
    fn bad_values_rejected() {
        assert!(from_toml_str("task = \"nope\"").is_err());
        assert!(from_toml_str("task = \"tiny\"\n[robustness]\nattack = \"x\"").is_err());
        assert!(from_toml_str("task = \"tiny\"\n[topology]\nkind = \"ring\"").is_err());
        // validation: byzantine majority
        assert!(from_toml_str("task = \"tiny\"\n[nodes]\nn = 4\nbyzantine = 2").is_err());
    }
}
