//! Experiment configuration: schema, TOML loading, per-figure presets.

pub mod file;
pub mod presets;
pub mod toml;

use crate::aggregation::gossip::GossipRuleKind;
use crate::aggregation::RuleKind;
use crate::attacks::AttackKind;
use crate::data::TaskKind;

pub use crate::util::vclock::{AsyncCfg, StalePolicyKind, StragglerKind};
pub use crate::wire::codec::Compression;

/// Crash-recovery knobs (the `[recovery]` TOML section): durable round
/// checkpoints, supervised shard-worker restart, and the deterministic
/// retry/backoff policy on the peer-pull path. The default value keeps
/// checkpointing off but restart supervision on — a crashed worker is
/// respawned (up to `max_worker_restarts` times per worker) instead of
/// aborting the run. Every knob is *modeled*: attempt budgets and
/// backoff schedules come from here, never from wall-clock reads, so a
/// recovered run stays bit-identical to an unfaulted one.
#[derive(Clone, Debug, PartialEq)]
pub struct RecoveryCfg {
    /// Directory for durable round checkpoints (`--checkpoint-dir`).
    /// Empty = checkpointing off.
    pub checkpoint_dir: String,
    /// Write a checkpoint every k rounds (`--checkpoint-every`, >= 1).
    pub checkpoint_every: usize,
    /// Coordinator-side deadline (seconds) for a spawned shard worker to
    /// connect and complete its handshake — and, when restart
    /// supervision is on, the per-phase socket read deadline that turns
    /// a *hung* worker into a detectable fault. Was a hard-coded 60s.
    pub handshake_timeout_secs: u64,
    /// Times one crashed/hung shard worker is respawned before the old
    /// named error surfaces. 0 = restart supervision off (a worker death
    /// aborts the run, pre-recovery behavior, and no per-round state
    /// sync traffic is exchanged).
    pub max_worker_restarts: usize,
    /// Attempt budget for peer pulls and peer dials (>= 1). 1 = a single
    /// try, no retry (pre-recovery behavior).
    pub retry_attempts: usize,
    /// Base of the deterministic backoff schedule: attempt k (0-based)
    /// sleeps `retry_backoff_ms << k` milliseconds before retrying. The
    /// schedule is a pure function of the config — no clock reads on the
    /// retry decision path.
    pub retry_backoff_ms: u64,
}

impl Default for RecoveryCfg {
    fn default() -> Self {
        RecoveryCfg {
            checkpoint_dir: String::new(),
            checkpoint_every: 1,
            handshake_timeout_secs: 60,
            max_worker_restarts: 2,
            retry_attempts: 3,
            retry_backoff_ms: 10,
        }
    }
}

impl RecoveryCfg {
    /// Whether any per-round recovery machinery (worker state sync) runs.
    pub fn supervised(&self) -> bool {
        self.max_worker_restarts > 0
    }

    /// Whether durable checkpoints are written.
    pub fn checkpointing(&self) -> bool {
        !self.checkpoint_dir.is_empty()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.checkpoint_every == 0 {
            return Err("recovery.checkpoint_every must be >= 1, got 0".into());
        }
        if self.handshake_timeout_secs == 0 {
            return Err("recovery.handshake_timeout_secs must be >= 1, got 0".into());
        }
        if self.retry_attempts == 0 {
            return Err("recovery.retry_attempts must be >= 1, got 0".into());
        }
        Ok(())
    }
}

/// How nodes exchange models.
#[derive(Clone, Debug, PartialEq)]
pub enum Topology {
    /// RPEL: every round, every honest node pulls from `s` uniformly
    /// random peers (paper §3.3).
    Epidemic { s: usize },
    /// Push-based Epidemic Learning (De Vos et al. 2024) — the variant
    /// the paper argues is *not* Byzantine-safe (§3.3, Appendix D):
    /// honest nodes push to `s` random recipients, but attackers are not
    /// bound by `s` and flood every honest node each round. Included as
    /// the pull-vs-push ablation.
    EpidemicPush { s: usize },
    /// Fixed-graph baseline: a random connected graph with `edges` edges
    /// is drawn once; nodes gossip with their graph neighbors
    /// (paper Appendix C.2).
    FixedGraph { edges: usize },
}

/// Which aggregation family runs on top of the topology.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RuleChoice {
    /// Definition-5.1 rule over the pulled set (epidemic topology).
    Epidemic(RuleKind),
    /// Gossip rule over graph neighborhoods (fixed-graph topology).
    Gossip(GossipRuleKind),
}

impl RuleChoice {
    pub fn name(&self) -> &'static str {
        match self {
            RuleChoice::Epidemic(k) => k.name(),
            RuleChoice::Gossip(k) => k.name(),
        }
    }
}

/// Which compute engine executes train/eval/aggregate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT HLO executables via the PJRT CPU client — the production path
    /// (L2 model + L1 Pallas aggregation).
    Hlo,
    /// Native Rust MLP engine (differential-testing twin / fast path for
    /// wide baseline sweeps; see `model::native`).
    Native,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        Some(match s {
            "hlo" | "pjrt" => EngineKind::Hlo,
            "native" | "rust" => EngineKind::Native,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Hlo => "hlo",
            EngineKind::Native => "native",
        }
    }
}

/// How a `--procs N` coordinator talks to its shard-worker processes
/// (ignored when `procs <= 1`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// stdin/stdout pipes (the default): the coordinator broadcasts the
    /// full O(h·d) half-step table to every worker each round.
    Pipe,
    /// Stream sockets (unix-domain where available, else loopback TCP):
    /// workers serve each other's pulls directly and the coordinator
    /// ships only the digest + per-round routing table.
    Socket,
    /// Like `Socket`, but forces loopback TCP — the same listener code
    /// path that lets workers live on other hosts.
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<TransportKind> {
        Some(match s {
            "pipe" | "pipes" => TransportKind::Pipe,
            "socket" | "unix" => TransportKind::Socket,
            "tcp" => TransportKind::Tcp,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Pipe => "pipe",
            TransportKind::Socket => "socket",
            TransportKind::Tcp => "tcp",
        }
    }

    /// Whether this transport routes pulls worker-to-worker (anything
    /// but the pipe broadcast).
    pub fn is_socket(&self) -> bool {
        !matches!(self, TransportKind::Pipe)
    }
}

/// Complete specification of one training run.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub task: TaskKind,
    /// Architecture name in the artifact manifest (e.g. "mlp_mnistlike").
    pub arch: String,
    /// Total nodes n, Byzantine count b.
    pub n: usize,
    pub b: usize,
    pub topology: Topology,
    /// Effective adversaries b̂. None = run Algorithm 2 at startup.
    pub bhat: Option<usize>,
    pub rule: RuleChoice,
    pub attack: AttackKind,
    /// Rounds T, batch size, local steps per round (paper §C.3).
    pub rounds: usize,
    pub batch: usize,
    pub local_steps: usize,
    /// Piecewise-constant LR schedule: (from_round, lr), ascending.
    pub lr_schedule: Vec<(usize, f32)>,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Dirichlet heterogeneity α (paper §6.1).
    pub alpha: f64,
    pub samples_per_node: usize,
    pub test_samples: usize,
    /// Evaluate every k rounds (and always at the last round).
    pub eval_every: usize,
    pub seed: u64,
    pub engine: EngineKind,
    pub artifacts_dir: String,
    /// Worker threads for the per-node round phases: `0` = all available
    /// cores, `1` = the legacy serial path. Results are bit-identical for
    /// every value (the round engine's randomness is counter-keyed per
    /// node, never drawn from a shared sequential stream).
    pub threads: usize,
    /// Shard count for the round engine: honest nodes are partitioned
    /// into this many contiguous shard-owned ranges (clamped to the
    /// honest count at construction). `1` = the single-shard engine.
    /// Results are bit-identical for every value — the determinism suite
    /// enforces the full (shards × threads) grid.
    pub shards: usize,
    /// Shard **processes** for the round engine (`--procs`, default 1).
    /// With `procs > 1` the honest nodes are partitioned into that many
    /// contiguous ranges, each owned by a spawned `rpel shard-worker`
    /// process that ships its round digest over the wire; `1` keeps every
    /// shard in-process. Results are bit-identical for every value — the
    /// determinism suite pins `--procs 2` against the in-process engine.
    pub procs: usize,
    /// Wire transport for the shard-worker processes (`--transport`,
    /// default `pipe`). `socket`/`tcp` enable worker-side pull serving:
    /// the coordinator ships each worker the per-round routing table
    /// instead of the O(h·d) half-step table. Results are bit-identical
    /// for every value — the determinism suite pins the whole
    /// (transport × procs) grid.
    pub transport: TransportKind,
    /// Directory for the coordinator/worker unix sockets (`--socket-dir`).
    /// Empty = a unique directory under the system temp dir; either way
    /// a per-run subdirectory is created and removed on teardown.
    pub socket_dir: String,
    /// Asynchronous-round knobs (`[async]` in TOML; named `asyn` because
    /// `async` is a Rust keyword): quorum round-close, virtual deadline,
    /// bounded staleness, straggler distribution, crash/rejoin churn —
    /// all on the deterministic virtual clock ([`crate::util::vclock`]).
    /// The default value is the synchronous engine; any fixed async
    /// config is itself bit-identical across the whole
    /// (transport × procs × shards × threads) grid.
    pub asyn: AsyncCfg,
    /// Per-round partial participation (`--participation`, default 1.0):
    /// each honest node joins a round iff its counter-keyed
    /// `(seed, round, node, PARTICIPATE)` coin lands below this fraction.
    /// Inactive nodes skip the half-step entirely (data RNG and momentum
    /// frozen), serve their committed params to pullers, and neither
    /// aggregate nor commit — so the per-round cost tracks the active
    /// set. Because the coin is a pure function of its key, a fixed
    /// `participation < 1` config is bit-identical across the whole
    /// (transport × procs × shards × threads) grid; `1.0` reproduces the
    /// full-participation engine bit-for-bit.
    pub participation: f64,
    /// Wire row-block compression (`[wire] compression` in TOML,
    /// `--compression`, default `none`): `Snapshot`/`PullReply` rows
    /// travel as deterministic f16 or q8 deltas against the round's
    /// digest mean. The decode is part of the wire spec — every path
    /// (in-process, pipe, socket/tcp, virtual) aggregates the *decoded*
    /// bits, so a fixed level is a modeled accuracy knob that stays
    /// bit-identical across the whole grid, and `none` reproduces the
    /// uncompressed engine byte-for-byte. See [`crate::wire::codec`].
    pub compression: Compression,
    /// Virtual-node backend (`--virtual-nodes`, default false): committed
    /// per-node state lives as `(init seed, XOR round-delta log)` with
    /// lazy materialization for only the nodes touched each round — a
    /// representation change pinned bit-identical to the dense engine.
    /// In-process only (`procs = 1`), epidemic pull topology.
    /// See [`crate::coordinator::vnode`].
    pub virtual_nodes: bool,
    /// Crash-recovery knobs (`[recovery]` in TOML): durable round
    /// checkpoints, supervised shard-worker restart, and the
    /// deterministic peer-pull retry policy.
    /// See [`crate::coordinator::checkpoint`].
    pub recovery: RecoveryCfg,
}

impl ExperimentConfig {
    /// Sensible defaults for a small epidemic run; presets/TOML override.
    pub fn default_for(task: TaskKind) -> ExperimentConfig {
        ExperimentConfig {
            name: format!("default_{}", task.name()),
            task,
            arch: task.default_arch().to_string(),
            n: 20,
            b: 3,
            topology: Topology::Epidemic { s: 6 },
            bhat: None,
            rule: RuleChoice::Epidemic(RuleKind::NnmCwtm),
            attack: AttackKind::Alie,
            rounds: 100,
            batch: 16,
            local_steps: 1,
            lr_schedule: vec![(0, 0.5)],
            momentum: 0.9,
            weight_decay: 1e-4,
            alpha: 1.0,
            samples_per_node: 128,
            test_samples: 512,
            eval_every: 10,
            seed: 1,
            engine: EngineKind::Native,
            artifacts_dir: "artifacts".to_string(),
            threads: 0,
            shards: 1,
            procs: 1,
            transport: TransportKind::Pipe,
            socket_dir: String::new(),
            asyn: AsyncCfg::default(),
            participation: 1.0,
            compression: Compression::None,
            virtual_nodes: false,
            recovery: RecoveryCfg::default(),
        }
    }

    /// LR at a given round (piecewise-constant schedule).
    pub fn lr_at(&self, round: usize) -> f32 {
        let mut lr = self.lr_schedule.first().map(|&(_, v)| v).unwrap_or(0.1);
        for &(from, v) in &self.lr_schedule {
            if round >= from {
                lr = v;
            }
        }
        lr
    }

    /// Honest node count |H| = n − b.
    pub fn honest(&self) -> usize {
        self.n - self.b
    }

    /// **Nominal** messages exchanged per round: n·s for epidemic pulls,
    /// 2·|E| for a gossip round (each edge carries one model in each
    /// direction) — the communication-budget bookkeeping behind figures
    /// 4–7. In push mode the Byzantine nodes flood (b·|H| extra
    /// messages): exactly the cost asymmetry the pull design removes.
    ///
    /// This is the protocol's *budget*, not what actually arrives: DoS
    /// rounds withhold every Byzantine response and push mode wastes
    /// pushes addressed to Byzantine recipients. The per-round *delivered*
    /// count (models honest nodes actually received) is recorded by the
    /// trainer in [`crate::metrics::History::delivered_per_round`].
    pub fn messages_per_round(&self) -> usize {
        match self.topology {
            Topology::Epidemic { s } => self.n * s,
            Topology::EpidemicPush { s } => (self.n - self.b) * s + self.b * (self.n - self.b),
            Topology::FixedGraph { edges } => 2 * edges,
        }
    }

    /// Validate internal consistency; returns a descriptive error string.
    pub fn validate(&self) -> Result<(), String> {
        if self.n < 2 {
            return Err("need at least 2 nodes".into());
        }
        if self.b >= self.n.div_ceil(2) {
            // the enforced bound is ⌈n/2⌉, and the message must quote
            // exactly that: for n = 5 the old text printed "n/2 = 2"
            // (floor) while b = 2 was in fact accepted — the real
            // rejection threshold is 3
            return Err(format!(
                "Byzantine majority: b={} must be < ⌈n/2⌉ = {}",
                self.b,
                self.n.div_ceil(2)
            ));
        }
        match self.topology {
            Topology::Epidemic { s } => {
                if s == 0 || s > self.n - 1 {
                    return Err(format!("s={s} must be in [1, n-1] = [1, {}]", self.n - 1));
                }
                if let Some(bh) = self.bhat {
                    if 2 * bh >= s + 1 {
                        return Err(format!(
                            "effective adversarial fraction {bh}/{} ≥ 1/2: \
                             no (s, b̂, κ)-robust rule exists (Def. 5.1)",
                            s + 1
                        ));
                    }
                }
                if matches!(self.rule, RuleChoice::Gossip(_)) {
                    return Err("gossip rules need a fixed-graph topology".into());
                }
            }
            Topology::EpidemicPush { s } => {
                if s == 0 || s > self.n - 1 {
                    return Err(format!("s={s} must be in [1, n-1] = [1, {}]", self.n - 1));
                }
                if matches!(self.rule, RuleChoice::Gossip(_)) {
                    return Err("gossip rules need a fixed-graph topology".into());
                }
                if self.engine == EngineKind::Hlo {
                    return Err(
                        "push mode has variable receive-set sizes; the fixed-shape \
                         HLO aggregate cannot apply — use engine = \"native\""
                            .into(),
                    );
                }
            }
            Topology::FixedGraph { edges } => {
                if edges < self.n - 1 {
                    return Err(format!(
                        "edges={edges} below spanning-tree minimum {}",
                        self.n - 1
                    ));
                }
                if matches!(self.rule, RuleChoice::Epidemic(_)) {
                    return Err("epidemic rules need the epidemic topology".into());
                }
            }
        }
        if self.rounds == 0 || self.batch == 0 || self.samples_per_node == 0 {
            return Err("rounds, batch, samples_per_node must be positive".into());
        }
        if self.shards == 0 {
            return Err("shards must be >= 1 (it partitions the honest nodes)".into());
        }
        if self.procs == 0 {
            return Err("procs must be >= 1 (shard processes; 1 = in-process)".into());
        }
        if self.lr_schedule.is_empty() {
            return Err("empty lr schedule".into());
        }
        if !(0.0..1.0).contains(&(self.momentum as f64)) {
            return Err(format!("momentum {} outside [0,1)", self.momentum));
        }
        if !self.alpha.is_finite()
            || !self.weight_decay.is_finite()
            || self.lr_schedule.iter().any(|&(_, lr)| !lr.is_finite())
        {
            return Err("alpha, weight_decay, and lr values must be finite".into());
        }
        self.asyn.validate()?;
        if self.asyn.quorum > self.honest() {
            return Err(format!(
                "async.quorum {} exceeds the honest count {}",
                self.asyn.quorum,
                self.honest()
            ));
        }
        if !self.participation.is_finite() || !(self.participation > 0.0) || self.participation > 1.0
        {
            return Err(format!(
                "participation {} must be in (0, 1]",
                self.participation
            ));
        }
        if self.participation < 1.0 && !matches!(self.topology, Topology::Epidemic { .. }) {
            return Err(
                "participation < 1 needs the epidemic pull topology (push floods and \
                 gossip graphs have no inactive-node serve semantics)"
                    .into(),
            );
        }
        if self.virtual_nodes {
            if !matches!(self.topology, Topology::Epidemic { .. }) {
                return Err("virtual_nodes needs the epidemic pull topology".into());
            }
            if self.procs > 1 {
                return Err(
                    "virtual_nodes is the in-process sparse backend; use procs = 1".into(),
                );
            }
        }
        self.recovery.validate()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        for task in [
            TaskKind::Tiny,
            TaskKind::MnistLike,
            TaskKind::CifarLike,
            TaskKind::FemnistLike,
        ] {
            ExperimentConfig::default_for(task).validate().unwrap();
        }
    }

    #[test]
    fn lr_schedule_staircase() {
        let mut cfg = ExperimentConfig::default_for(TaskKind::CifarLike);
        // the paper's CIFAR staircase
        cfg.lr_schedule = vec![(0, 0.5), (500, 0.1), (1000, 0.02), (1500, 0.004)];
        assert_eq!(cfg.lr_at(0), 0.5);
        assert_eq!(cfg.lr_at(499), 0.5);
        assert_eq!(cfg.lr_at(500), 0.1);
        assert_eq!(cfg.lr_at(1200), 0.02);
        assert_eq!(cfg.lr_at(9999), 0.004);
    }

    #[test]
    fn validation_rejects_byzantine_majority() {
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.n = 10;
        cfg.b = 5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_rejects_eaf_breakdown() {
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.topology = Topology::Epidemic { s: 5 };
        cfg.bhat = Some(3); // 3/6 = 1/2
        assert!(cfg.validate().unwrap_err().contains("1/2"));
    }

    #[test]
    fn validation_rejects_rule_topology_mismatch() {
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
        assert!(cfg.validate().is_err());
        cfg.topology = Topology::FixedGraph { edges: 60 };
        assert!(cfg.validate().is_ok());
        cfg.rule = RuleChoice::Epidemic(RuleKind::NnmCwtm);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_rejects_zero_shards() {
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.shards = 0;
        assert!(cfg.validate().unwrap_err().contains("shards"));
        cfg.shards = 5;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn validation_rejects_non_finite_floats() {
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.weight_decay = f32::INFINITY;
        assert!(cfg.validate().unwrap_err().contains("finite"));
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.alpha = f64::NAN;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.lr_schedule = vec![(0, f32::NAN)];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_rejects_zero_procs() {
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.procs = 0;
        assert!(cfg.validate().unwrap_err().contains("procs"));
        cfg.procs = 2;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn validation_rejects_async_misconfig() {
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.asyn.quorum = cfg.honest() + 1;
        assert!(cfg.validate().unwrap_err().contains("quorum"));
        cfg.asyn.quorum = cfg.honest();
        assert!(cfg.validate().is_ok());
        cfg.asyn.stale_decay = -0.5;
        assert!(cfg.validate().unwrap_err().contains("stale_decay"));
    }

    #[test]
    fn validation_rejects_sparse_misconfig() {
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.participation = 0.0;
        assert!(cfg.validate().unwrap_err().contains("participation"));
        cfg.participation = 1.5;
        assert!(cfg.validate().is_err());
        cfg.participation = f64::NAN;
        assert!(cfg.validate().is_err());
        cfg.participation = 0.5;
        assert!(cfg.validate().is_ok());
        cfg.topology = Topology::EpidemicPush { s: 6 };
        assert!(cfg.validate().unwrap_err().contains("epidemic pull"));

        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.virtual_nodes = true;
        assert!(cfg.validate().is_ok());
        cfg.procs = 2;
        assert!(cfg.validate().unwrap_err().contains("procs"));
        cfg.procs = 1;
        cfg.topology = Topology::FixedGraph { edges: 60 };
        cfg.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
        assert!(cfg.validate().unwrap_err().contains("virtual_nodes"));
    }

    #[test]
    fn rejection_messages_quote_the_exact_bound() {
        // one row per validate() arm with a numeric bound: the message
        // must quote the bound it actually enforces (the old Byzantine-
        // majority text printed the floor, n/2, while enforcing ⌈n/2⌉)
        type Mutator = fn(&mut ExperimentConfig);
        let cases: &[(Mutator, &str)] = &[
            (
                |c| {
                    c.n = 5;
                    c.b = 3;
                },
                "Byzantine majority: b=3 must be < ⌈n/2⌉ = 3",
            ),
            (
                |c| {
                    c.n = 5;
                    c.b = 2;
                    c.topology = Topology::Epidemic { s: 0 };
                },
                "s=0 must be in [1, n-1] = [1, 4]",
            ),
            (
                |c| {
                    c.n = 5;
                    c.b = 2;
                    c.topology = Topology::EpidemicPush { s: 7 };
                },
                "s=7 must be in [1, n-1] = [1, 4]",
            ),
            (
                |c| c.participation = 1.5,
                "participation 1.5 must be in (0, 1]",
            ),
            (|c| c.momentum = 1.0, "momentum 1 outside [0,1)"),
            (
                |c| c.asyn.quorum = 18,
                "async.quorum 18 exceeds the honest count 17",
            ),
            (
                |c| {
                    c.topology = Topology::FixedGraph { edges: 10 };
                    c.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
                },
                "edges=10 below spanning-tree minimum 19",
            ),
            (
                |c| c.recovery.checkpoint_every = 0,
                "recovery.checkpoint_every must be >= 1, got 0",
            ),
            (
                |c| c.recovery.handshake_timeout_secs = 0,
                "recovery.handshake_timeout_secs must be >= 1, got 0",
            ),
            (
                |c| c.recovery.retry_attempts = 0,
                "recovery.retry_attempts must be >= 1, got 0",
            ),
        ];
        for (i, (mutate, want)) in cases.iter().enumerate() {
            let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
            mutate(&mut cfg);
            let got = cfg.validate().unwrap_err();
            assert_eq!(&got, want, "case {i}");
        }
        // the point the old floor-printed message claimed was out of
        // bounds ("b=2 must be < n/2 = 2" at n=5) is in fact accepted:
        // the enforced threshold is ⌈5/2⌉ = 3
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        cfg.n = 5;
        cfg.b = 2;
        cfg.topology = Topology::Epidemic { s: 4 };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn message_budget_matches_paper_accounting() {
        let mut cfg = ExperimentConfig::default_for(TaskKind::MnistLike);
        cfg.n = 100;
        cfg.topology = Topology::Epidemic { s: 15 };
        assert_eq!(cfg.messages_per_round(), 1500);
        // the paper matches fixed graphs by K = n*s/2 edges = same messages
        cfg.topology = Topology::FixedGraph { edges: 750 };
        cfg.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
        assert_eq!(cfg.messages_per_round(), 1500);
    }
}
