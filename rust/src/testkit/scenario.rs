//! Named asynchrony scenarios for the scenario-grade test tier.
//!
//! A [`Scenario`] is a reusable `[async]` configuration with a name:
//! stragglers, crash/rejoin churn, a partition that heals. Scenarios
//! round-trip through TOML **exactly** (the serializer is the same one
//! the coordinator uses to ship configs to shard workers), so a scenario
//! pinned in a test is the same scenario a user can put in a config
//! file. `rust/tests/scenario_chaos.rs` drives every named scenario
//! end-to-end and checks the runs converge, stay bit-reproducible, and
//! keep their ledgers consistent.

use crate::config::file::{async_from_doc, async_to_toml};
use crate::config::toml::parse;
use crate::config::{AsyncCfg, ExperimentConfig, StalePolicyKind, StragglerKind};

/// A named `[async]` configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub asyn: AsyncCfg,
}

impl Scenario {
    /// Look up a built-in scenario by name.
    pub fn named(name: &str) -> Option<Scenario> {
        Scenario::all().into_iter().find(|s| s.name == name)
    }

    /// Every built-in scenario. Quorums are sized for the small worlds
    /// the scenario tests run (honest count ≥ 10); `apply` + `validate`
    /// rejects a scenario that asks for more arrivals than a config's
    /// honest population can produce.
    pub fn all() -> Vec<Scenario> {
        vec![
            // two-point stragglers: most nodes fast, a slow minority;
            // the round closes on the quorum, slow nodes carry forward
            Scenario {
                name: "straggler_twopoint".into(),
                asyn: AsyncCfg {
                    quorum: 7,
                    max_staleness: 2,
                    straggler: StragglerKind::TwoPoint,
                    slow_prob: 0.3,
                    slow_latency: 4.0,
                    ..AsyncCfg::default()
                },
            },
            // heavy-tailed lognormal stragglers with decayed stale rows
            Scenario {
                name: "straggler_lognormal".into(),
                asyn: AsyncCfg {
                    quorum: 8,
                    max_staleness: 3,
                    stale_policy: StalePolicyKind::Decay,
                    stale_decay: 0.5,
                    straggler: StragglerKind::LogNormal,
                    sigma: 0.5,
                    ..AsyncCfg::default()
                },
            },
            // crash/rejoin churn: nodes drop for `down_rounds` rounds
            // and rejoin; constant latency isolates the churn effect
            Scenario {
                name: "crash_recover".into(),
                asyn: AsyncCfg {
                    quorum: 6,
                    max_staleness: 2,
                    crash_prob: 0.15,
                    down_rounds: 2,
                    ..AsyncCfg::default()
                },
            },
            // a partition takes out a node block mid-run, then heals
            Scenario {
                name: "partition_heal".into(),
                asyn: AsyncCfg {
                    quorum: 6,
                    max_staleness: 3,
                    part_from: 2,
                    part_to: 5,
                    part_nodes: 3,
                    ..AsyncCfg::default()
                },
            },
        ]
    }

    /// Serialize as TOML: a `name` key plus the same `[async]` section
    /// [`crate::config::file::to_toml_str`] emits.
    pub fn to_toml_str(&self) -> String {
        let mut out = format!("name = \"{}\"\n", self.name);
        async_to_toml(&mut out, &self.asyn);
        out
    }

    /// Parse a scenario back from TOML. `from_toml_str(to_toml_str(s))`
    /// must reproduce `s` field-for-field (pinned per scenario in
    /// `rust/tests/scenario_chaos.rs`).
    pub fn from_toml_str(text: &str) -> Result<Scenario, String> {
        let doc = parse(text).map_err(|e| e.to_string())?;
        let name = doc
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| "scenario is missing a 'name' string".to_string())?
            .to_string();
        let mut asyn = AsyncCfg::default();
        async_from_doc(&doc, &mut asyn)?;
        asyn.validate()?;
        Ok(Scenario { name, asyn })
    }

    /// Install this scenario's `[async]` section on a config. The
    /// combined config is re-validated (a quorum larger than the
    /// config's honest population is rejected here, not at run time).
    pub fn apply(&self, cfg: &mut ExperimentConfig) -> Result<(), String> {
        cfg.asyn = self.asyn.clone();
        cfg.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskKind;

    #[test]
    fn every_builtin_scenario_is_enabled_and_valid() {
        let all = Scenario::all();
        assert_eq!(all.len(), 4);
        for s in &all {
            assert!(s.asyn.is_enabled(), "{} must enable the async engine", s.name);
            s.asyn.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name));
        }
    }

    #[test]
    fn named_lookup_finds_each_scenario_once() {
        for s in Scenario::all() {
            assert_eq!(Scenario::named(&s.name), Some(s.clone()));
        }
        assert_eq!(Scenario::named("no_such_scenario"), None);
    }

    #[test]
    fn toml_round_trip_is_exact() {
        for s in Scenario::all() {
            let text = s.to_toml_str();
            let back = Scenario::from_toml_str(&text)
                .unwrap_or_else(|e| panic!("{}: reparse failed: {e}\n---\n{text}", s.name));
            assert_eq!(back, s, "round-trip mismatch for:\n{text}");
        }
    }

    #[test]
    fn apply_installs_and_validates() {
        let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
        let s = Scenario::named("crash_recover").unwrap();
        s.apply(&mut cfg).unwrap();
        assert_eq!(cfg.asyn, s.asyn);

        // a quorum past the honest population must be rejected on apply
        let mut tiny = ExperimentConfig::default_for(TaskKind::Tiny);
        tiny.n = 6;
        tiny.b = 1;
        let too_big = Scenario {
            name: "overquorum".into(),
            asyn: AsyncCfg {
                quorum: 9,
                ..AsyncCfg::default()
            },
        };
        assert!(too_big.apply(&mut tiny).is_err());
    }
}
