//! Deterministic fault injection for the wire transports.
//!
//! Two layers, matching the two layers of the transport stack:
//!
//! * [`ChaosStream`] wraps any `Read`/`Write` byte stream and perturbs
//!   the *byte* level: split reads (fewer bytes than asked), short
//!   writes (partial `write` returns), EOF mid-frame after a byte
//!   budget, and fixed delays. Chunk sizes come from the counter RNG
//!   keyed `(seed, op_index, 0, CHAOS)` — a chaotic run reproduces
//!   exactly from its seed.
//! * [`ChaosTransport`] wraps a [`Transport`] and perturbs the *frame*
//!   level: delayed replies, a replayed earlier frame (how a reply
//!   stranded by an aborted round manifests — the stale-round case), and
//!   a stream cut after N frames (how a worker killed mid-protocol
//!   manifests to the peer still reading).
//!
//! The test suite (`rust/tests/transport_faults.rs`) drives both pipe
//! and socket paths through these wrappers and asserts every fault
//! surfaces as an actionable error naming the worker and round — never
//! a hang, never silent corruption.

use crate::util::rng::{stream_tag, Rng};
use crate::wire::transport::Transport;
use anyhow::{bail, Result};
use std::io::{Read, Write};
use std::time::Duration;

/// Byte-level fault wrapper. All faults default to off; enable the ones
/// a test needs with the builder methods.
pub struct ChaosStream<S> {
    inner: S,
    seed: u64,
    ops: u64,
    split_reads: bool,
    short_writes: bool,
    /// Stop yielding bytes (EOF) after this many bytes have been read —
    /// lands mid-frame by construction in the tests.
    eof_after: Option<u64>,
    /// Sleep this long before every read (a slow peer, not a dead one).
    read_delay: Option<Duration>,
    bytes_read: u64,
}

impl<S> ChaosStream<S> {
    pub fn new(inner: S, seed: u64) -> ChaosStream<S> {
        ChaosStream {
            inner,
            seed,
            ops: 0,
            split_reads: false,
            short_writes: false,
            eof_after: None,
            read_delay: None,
            bytes_read: 0,
        }
    }

    /// Reads return 1–3 bytes at a time regardless of how many were asked.
    pub fn split_reads(mut self) -> Self {
        self.split_reads = true;
        self
    }

    /// Writes accept 1–3 bytes at a time regardless of how many were given.
    pub fn short_writes(mut self) -> Self {
        self.short_writes = true;
        self
    }

    /// Simulate the peer dying after `n` bytes: reads hit EOF mid-frame.
    pub fn eof_after(mut self, n: u64) -> Self {
        self.eof_after = Some(n);
        self
    }

    /// Sleep before every read — a delayed (but correct) reply.
    pub fn read_delay(mut self, d: Duration) -> Self {
        self.read_delay = Some(d);
        self
    }

    /// 1..=3, a pure function of (seed, op counter).
    fn chunk(&mut self) -> usize {
        let mut rng = Rng::stream(self.seed, self.ops, 0, stream_tag::CHAOS);
        self.ops += 1;
        1 + rng.index(3)
    }
}

impl<S: Read> Read for ChaosStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if let Some(d) = self.read_delay {
            std::thread::sleep(d);
        }
        let mut max = buf.len();
        if self.split_reads {
            max = max.min(self.chunk());
        }
        if let Some(cap) = self.eof_after {
            let left = cap.saturating_sub(self.bytes_read) as usize;
            if left == 0 {
                return Ok(0); // the "peer" is gone: clean EOF mid-frame
            }
            max = max.min(left);
        }
        let n = self.inner.read(&mut buf[..max])?;
        self.bytes_read += n as u64;
        Ok(n)
    }
}

impl<S: Write> Write for ChaosStream<S> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let mut max = buf.len();
        if self.short_writes {
            max = max.min(self.chunk());
        }
        self.inner.write(&buf[..max])
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// Frame-level fault schedule for [`ChaosTransport`].
#[derive(Clone, Debug, Default)]
pub struct ChaosPlan {
    /// Sleep before delivering every received frame (delayed replies).
    pub recv_delay: Option<Duration>,
    /// `(at, from)`: deliver, in place of the `at`-th received frame
    /// (0-based), a byte-exact replay of the `from`-th — the stale-reply
    /// case an aborted round leaves behind.
    pub replay: Option<(u64, u64)>,
    /// Error out (as a mid-frame stream death) on the `n`-th receive.
    pub cut_at: Option<u64>,
}

/// Transport wrapper applying a [`ChaosPlan`]. Sends pass through
/// untouched — the faults model a misbehaving *peer*, not a corrupted
/// local encoder.
pub struct ChaosTransport {
    inner: Box<dyn Transport>,
    plan: ChaosPlan,
    recvd: u64,
    log: Vec<Vec<u8>>,
}

impl ChaosTransport {
    pub fn new(inner: Box<dyn Transport>, plan: ChaosPlan) -> ChaosTransport {
        ChaosTransport {
            inner,
            plan,
            recvd: 0,
            log: Vec::new(),
        }
    }
}

impl Transport for ChaosTransport {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        self.inner.send(payload)
    }

    fn recv_opt(&mut self) -> Result<Option<Vec<u8>>> {
        if let Some(d) = self.plan.recv_delay {
            std::thread::sleep(d);
        }
        let idx = self.recvd;
        if self.plan.cut_at == Some(idx) {
            bail!("wire: stream closed mid-frame body (chaos cut)");
        }
        if let Some((at, from)) = self.plan.replay {
            if idx == at {
                let frame = self
                    .log
                    .get(from as usize)
                    .cloned()
                    .expect("chaos replay source not yet received");
                self.recvd += 1;
                return Ok(Some(frame));
            }
        }
        let frame = self.inner.recv_opt()?;
        if let Some(f) = &frame {
            // retain only what a pending replay can still reference —
            // without this the log would grow by O(table) per round
            let keep = self
                .plan
                .replay
                .map(|(_, from)| from as usize + 1)
                .unwrap_or(0);
            if self.log.len() < keep {
                self.log.push(f.clone());
            }
            self.recvd += 1;
        }
        Ok(frame)
    }

    fn bytes_out(&self) -> u64 {
        self.inner.bytes_out()
    }

    fn bytes_in(&self) -> u64 {
        self.inner.bytes_in()
    }

    fn shutdown(&mut self) {
        self.inner.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire;

    #[test]
    fn chaos_chunks_are_deterministic_per_seed() {
        let sizes = |seed| {
            let mut s = ChaosStream::new(std::io::empty(), seed).split_reads();
            (0..32).map(|_| s.chunk()).collect::<Vec<_>>()
        };
        assert_eq!(sizes(7), sizes(7));
        assert_ne!(sizes(7), sizes(8));
        assert!(sizes(7).iter().all(|&c| (1..=3).contains(&c)));
    }

    #[test]
    fn split_reads_and_short_writes_preserve_frames() {
        let mut wire_bytes = Vec::new();
        {
            let mut w = ChaosStream::new(&mut wire_bytes, 1).short_writes();
            wire::write_frame(&mut w, b"the quick brown fox").unwrap();
            wire::write_frame(&mut w, b"").unwrap();
            w.flush().unwrap();
        }
        let mut r = ChaosStream::new(std::io::Cursor::new(wire_bytes), 2).split_reads();
        assert_eq!(wire::read_frame(&mut r).unwrap(), b"the quick brown fox");
        assert_eq!(wire::read_frame(&mut r).unwrap(), b"");
        assert!(wire::read_frame_opt(&mut r).unwrap().is_none());
    }

    #[test]
    fn eof_mid_frame_is_an_error_not_a_hang() {
        let mut buf = Vec::new();
        wire::write_frame(&mut buf, &[0xAB; 64]).unwrap();
        for cut in [2u64, 4, 5, 40] {
            let mut r = ChaosStream::new(std::io::Cursor::new(buf.clone()), 3).eof_after(cut);
            let err = wire::read_frame(&mut r).unwrap_err().to_string();
            assert!(err.contains("mid-frame"), "cut={cut}: {err}");
        }
    }
}
