//! Property-testing mini-harness (the offline crate set lacks proptest),
//! plus the deterministic transport fault injector ([`chaos`]) and the
//! named asynchrony scenarios ([`scenario`]) the scenario test tier runs.
//!
//! A [`forall`] runner drives a generator against a property over many
//! seeded cases; on failure it performs greedy shrinking (halving vectors,
//! bisecting integers, zeroing floats) and reports the minimal
//! counterexample together with the seed that reproduces it.
//!
//! ```no_run
//! // (no_run: doctest binaries bypass the crate's rpath on this image —
//! //  the same snippet executes in `tests::passing_property_completes`)
//! use rpel::testkit::{forall, Gen};
//! forall(100, 42, Gen::vec_f32(1..=8, -10.0..10.0), |v| {
//!     v.iter().all(|x| x.abs() <= 10.0)
//! });
//! ```

pub mod chaos;
pub mod scenario;

use crate::util::rng::Rng;

/// A seeded generator of test inputs, plus a shrinking strategy.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Rng) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + std::fmt::Debug + 'static> Gen<T> {
    pub fn new(
        gen: impl Fn(&mut Rng) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Gen {
            gen: Box::new(gen),
            shrink: Box::new(shrink),
        }
    }

    /// Generator with no shrinking.
    pub fn plain(gen: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Gen::new(gen, |_| Vec::new())
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.gen)(rng)
    }

    pub fn shrinks(&self, value: &T) -> Vec<T> {
        (self.shrink)(value)
    }

    /// Map the generated value (shrinks are lost — use for derived views).
    pub fn map<U: Clone + std::fmt::Debug + 'static>(
        self,
        f: impl Fn(T) -> U + 'static,
    ) -> Gen<U> {
        Gen::plain(move |rng| f(self.sample(rng)))
    }
}

impl Gen<usize> {
    /// Uniform usize in [lo, hi], shrinking toward lo.
    pub fn usize_in(range: std::ops::RangeInclusive<usize>) -> Gen<usize> {
        let (lo, hi) = (*range.start(), *range.end());
        Gen::new(
            move |rng| lo + rng.index(hi - lo + 1),
            move |&v| {
                let mut out = Vec::new();
                if v > lo {
                    // geometric ladder of midpoints for fast bisection,
                    // then the immediate predecessor
                    out.push(lo);
                    for k in 1..8usize {
                        out.push(lo + (v - lo) * k / 8);
                    }
                    out.push(v - 1);
                    out.dedup();
                    out.retain(|&c| c < v);
                }
                out
            },
        )
    }
}

impl Gen<f32> {
    /// Uniform f32 in [lo, hi), shrinking toward 0 (clamped into range).
    pub fn f32_in(range: std::ops::Range<f32>) -> Gen<f32> {
        let (lo, hi) = (range.start, range.end);
        Gen::new(
            move |rng| lo + (hi - lo) * rng.f32(),
            move |&v| {
                let zero = 0.0f32.clamp(lo, hi);
                if v != zero {
                    vec![zero, v / 2.0]
                } else {
                    vec![]
                }
            },
        )
    }
}

impl Gen<Vec<f32>> {
    /// Vector of uniform f32s with random length, shrinking by halving
    /// length and zeroing entries.
    pub fn vec_f32(
        len: std::ops::RangeInclusive<usize>,
        range: std::ops::Range<f32>,
    ) -> Gen<Vec<f32>> {
        let (llo, lhi) = (*len.start(), *len.end());
        let (lo, hi) = (range.start, range.end);
        Gen::new(
            move |rng| {
                let n = llo + rng.index(lhi - llo + 1);
                (0..n).map(|_| lo + (hi - lo) * rng.f32()).collect()
            },
            move |v: &Vec<f32>| {
                let mut out = Vec::new();
                if v.len() > llo {
                    out.push(v[..v.len() / 2.max(llo)].to_vec());
                    out.push(v[..v.len() - 1].to_vec());
                }
                if v.iter().any(|&x| x != 0.0) {
                    out.push(vec![0.0f32.clamp(lo, hi); v.len()]);
                }
                out
            },
        )
    }
}

/// Pair two independent generators.
pub fn zip<A, B>(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)>
where
    A: Clone + std::fmt::Debug + 'static,
    B: Clone + std::fmt::Debug + 'static,
{
    Gen::new(
        move |rng| (a.sample(rng), b.sample(rng)),
        |_| Vec::new(),
    )
}

/// Run `prop` over `cases` generated inputs. Panics with a shrunk, seeded
/// counterexample on failure.
pub fn forall<T: Clone + std::fmt::Debug + 'static>(
    cases: usize,
    seed: u64,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(&gen, input, &prop);
            panic!(
                "property failed (seed={seed}, case={case})\nminimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<T: Clone + std::fmt::Debug + 'static>(
    gen: &Gen<T>,
    mut failing: T,
    prop: &impl Fn(&T) -> bool,
) -> T {
    // greedy: accept any shrink that still fails, cap total attempts
    let mut budget = 200usize;
    'outer: while budget > 0 {
        for cand in gen.shrinks(&failing) {
            budget -= 1;
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
            if budget == 0 {
                break;
            }
        }
        break;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(200, 1, Gen::vec_f32(0..=16, -5.0..5.0), |v| {
            v.iter().all(|x| x.abs() <= 5.0)
        });
    }

    #[test]
    fn usize_gen_respects_range() {
        forall(500, 2, Gen::usize_in(3..=9), |&n| (3..=9).contains(&n));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(100, 3, Gen::usize_in(0..=100), |&n| n < 90);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // capture the panic message and check the counterexample is minimal
        let result = std::panic::catch_unwind(|| {
            forall(100, 4, Gen::usize_in(0..=1000), |&n| n < 8);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // geometric bisection must land on the boundary value 8
        assert!(
            msg.contains("counterexample: 8"),
            "msg: {msg}"
        );
    }

    #[test]
    fn map_transforms() {
        let g = Gen::usize_in(1..=4).map(|n| vec![0u8; n]);
        forall(100, 5, g, |v| (1..=4).contains(&v.len()));
    }

    #[test]
    fn zip_pairs() {
        let g = zip(Gen::usize_in(0..=3), Gen::f32_in(0.0..1.0));
        forall(100, 6, g, |&(n, x)| n <= 3 && (0.0..1.0).contains(&x));
    }
}
