//! Native (Rust) MLP engine.
//!
//! The production training path executes the AOT-compiled JAX graphs via
//! [`crate::runtime`]; this module provides a bit-compatible *native*
//! implementation of the MLP architectures for three purposes:
//!
//! 1. **Differential testing** — native forward/eval must match the HLO
//!    executables and the `model_fixtures.json` oracle within tolerance.
//! 2. **Fast engine** for the baseline s-grid figures (figs 4–7 sweep many
//!    (s, rule, attack) cells; the native path avoids per-cell PJRT
//!    dispatch overhead on this 1-core testbed).
//! 3. Running without artifacts (e.g. `cargo test` before `make artifacts`).
//!
//! The flat parameter layout matches `jax.flatten_util.ravel_pytree` over
//! the Python-side pytree `[{"b": b, "w": w}, ...]`: **per layer, bias
//! first, then the (fan_in × out) weight matrix in row-major order** (JAX
//! flattens dict keys in sorted order).

pub mod native;

pub use native::{MlpSpec, TrainHyper};
