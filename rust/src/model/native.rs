//! Forward / backward / momentum-SGD for the reduced-scale MLPs, matching
//! `python/compile/model.py` semantics exactly (He init is jax-side; the
//! native engine consumes flat params produced either by the HLO `init_*`
//! executable or by [`MlpSpec::init_native`]).

use crate::util::rng::Rng;

/// An MLP architecture: dense layers with ReLU, log-softmax head.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MlpSpec {
    pub name: String,
    pub din: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
}

/// Hyper-parameters of one momentum-SGD half-step (Algorithm 1 lines 3–6).
#[derive(Clone, Copy, Debug)]
pub struct TrainHyper {
    pub lr: f32,
    pub beta: f32,
    pub weight_decay: f32,
}

impl MlpSpec {
    pub fn new(name: &str, din: usize, hidden: &[usize], classes: usize) -> Self {
        MlpSpec {
            name: name.to_string(),
            din,
            hidden: hidden.to_vec(),
            classes,
        }
    }

    /// The reduced-scale model zoo (mirrors `model.SPECS` in Python).
    pub fn by_name(name: &str) -> Option<MlpSpec> {
        Some(match name {
            "mlp_tiny" => MlpSpec::new("mlp_tiny", 16, &[16], 4),
            "mlp_mnistlike" => MlpSpec::new("mlp_mnistlike", 64, &[64], 10),
            "mlp_cifarlike" => MlpSpec::new("mlp_cifarlike", 96, &[128, 64], 10),
            "mlp_femnistlike" => MlpSpec::new("mlp_femnistlike", 64, &[128], 62),
            _ => return None,
        })
    }

    /// Layer dims as (fan_in, fan_out) pairs.
    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        let mut dims = Vec::with_capacity(self.hidden.len() + 1);
        let mut prev = self.din;
        for &h in &self.hidden {
            dims.push((prev, h));
            prev = h;
        }
        dims.push((prev, self.classes));
        dims
    }

    /// Total flat parameter count d.
    pub fn param_count(&self) -> usize {
        self.layer_dims()
            .iter()
            .map(|&(i, o)| i * o + o)
            .sum()
    }

    /// Per-layer (bias_offset, weight_offset) in the flat vector — the
    /// ravel_pytree layout: [b₀, w₀, b₁, w₁, ...].
    fn offsets(&self) -> Vec<(usize, usize)> {
        let mut offs = Vec::new();
        let mut pos = 0;
        for (fan_in, fan_out) in self.layer_dims() {
            offs.push((pos, pos + fan_out));
            pos += fan_out + fan_in * fan_out;
        }
        offs
    }

    /// He-initialized flat params (native RNG; *not* bit-identical to the
    /// jax `init_*` executable, which exists for that purpose — this is the
    /// artifact-free fallback).
    pub fn init_native(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed ^ 0x1217);
        let mut params = vec![0.0f32; self.param_count()];
        let offs = self.offsets();
        for ((fan_in, fan_out), (_, woff)) in self.layer_dims().into_iter().zip(offs) {
            let std = (2.0 / fan_in as f64).sqrt() as f32;
            for k in 0..fan_in * fan_out {
                params[woff + k] = rng.gaussian32(0.0, std);
            }
        }
        params
    }

    /// Log-softmax forward pass. `x`: batch-major [n, din]; output [n,
    /// classes] log-probabilities written into `logp`.
    pub fn forward(&self, params: &[f32], x: &[f32], n: usize, logp: &mut Vec<f32>) {
        assert_eq!(params.len(), self.param_count(), "param size mismatch");
        assert_eq!(x.len(), n * self.din, "input size mismatch");
        let dims = self.layer_dims();
        let offs = self.offsets();
        let mut h: Vec<f32> = x.to_vec();
        let mut width = self.din;
        for (li, (&(fan_in, fan_out), &(boff, woff))) in
            dims.iter().zip(offs.iter()).enumerate()
        {
            debug_assert_eq!(width, fan_in);
            let w = &params[woff..woff + fan_in * fan_out];
            let b = &params[boff..boff + fan_out];
            let mut out = vec![0.0f32; n * fan_out];
            for r in 0..n {
                let hi = &h[r * fan_in..(r + 1) * fan_in];
                let oi = &mut out[r * fan_out..(r + 1) * fan_out];
                oi.copy_from_slice(b);
                // row-major (fan_in, fan_out) weight: accumulate rank-1 rows
                for (k, &hv) in hi.iter().enumerate() {
                    if hv != 0.0 {
                        let wrow = &w[k * fan_out..(k + 1) * fan_out];
                        for (o, &wv) in oi.iter_mut().zip(wrow) {
                            *o += hv * wv;
                        }
                    }
                }
            }
            let last = li == dims.len() - 1;
            if !last {
                for v in &mut out {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            h = out;
            width = fan_out;
        }
        // log-softmax rows
        logp.clear();
        logp.extend_from_slice(&h);
        for r in 0..n {
            let row = &mut logp[r * self.classes..(r + 1) * self.classes];
            let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let lse = row
                .iter()
                .map(|&v| ((v - max) as f64).exp())
                .sum::<f64>()
                .ln() as f32
                + max;
            for v in row {
                *v -= lse;
            }
        }
    }

    /// Mean NLL + L2 regularization, plus the gradient, via explicit
    /// backprop. Returns loss; writes gradient into `grad`.
    pub fn loss_grad(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        weight_decay: f32,
        grad: &mut [f32],
    ) -> f32 {
        let n = y.len();
        assert_eq!(x.len(), n * self.din);
        assert_eq!(grad.len(), params.len());
        let dims = self.layer_dims();
        let offs = self.offsets();

        // forward with cached activations
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(dims.len() + 1);
        acts.push(x.to_vec());
        let mut width = self.din;
        for (li, (&(fan_in, fan_out), &(boff, woff))) in
            dims.iter().zip(offs.iter()).enumerate()
        {
            debug_assert_eq!(width, fan_in);
            let w = &params[woff..woff + fan_in * fan_out];
            let b = &params[boff..boff + fan_out];
            let h = &acts[li];
            let mut out = vec![0.0f32; n * fan_out];
            for r in 0..n {
                let hi = &h[r * fan_in..(r + 1) * fan_in];
                let oi = &mut out[r * fan_out..(r + 1) * fan_out];
                oi.copy_from_slice(b);
                for (k, &hv) in hi.iter().enumerate() {
                    if hv != 0.0 {
                        let wrow = &w[k * fan_out..(k + 1) * fan_out];
                        for (o, &wv) in oi.iter_mut().zip(wrow) {
                            *o += hv * wv;
                        }
                    }
                }
            }
            if li != dims.len() - 1 {
                for v in &mut out {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            acts.push(out);
            width = fan_out;
        }

        // softmax + NLL on the last activation (pre-log-softmax logits)
        let logits = acts.last().unwrap();
        let c = self.classes;
        let mut delta = vec![0.0f32; n * c]; // dL/dlogits
        let mut loss = 0.0f64;
        for r in 0..n {
            let row = &logits[r * c..(r + 1) * c];
            let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut den = 0.0f64;
            for &v in row {
                den += ((v - max) as f64).exp();
            }
            let lse = den.ln() as f32 + max;
            let yi = y[r] as usize;
            loss += (lse - row[yi]) as f64;
            let drow = &mut delta[r * c..(r + 1) * c];
            for (j, dv) in drow.iter_mut().enumerate() {
                let p = (((row[j] - max) as f64).exp() / den) as f32;
                *dv = (p - if j == yi { 1.0 } else { 0.0 }) / n as f32;
            }
        }
        loss /= n as f64;

        // backprop
        grad.fill(0.0);
        let mut dl = delta;
        for li in (0..dims.len()).rev() {
            let (fan_in, fan_out) = dims[li];
            let (boff, woff) = offs[li];
            let h = &acts[li];
            // bias grad
            for r in 0..n {
                for j in 0..fan_out {
                    grad[boff + j] += dl[r * fan_out + j];
                }
            }
            // weight grad: dW[k,j] += h[r,k] * dl[r,j]
            for r in 0..n {
                let hi = &h[r * fan_in..(r + 1) * fan_in];
                let di = &dl[r * fan_out..(r + 1) * fan_out];
                for (k, &hv) in hi.iter().enumerate() {
                    if hv != 0.0 {
                        let grow = &mut grad[woff + k * fan_out..woff + (k + 1) * fan_out];
                        for (g, &dv) in grow.iter_mut().zip(di) {
                            *g += hv * dv;
                        }
                    }
                }
            }
            if li > 0 {
                // propagate: dh[r,k] = Σ_j W[k,j] dl[r,j], masked by ReLU
                let w = &params[woff..woff + fan_in * fan_out];
                let mut dh = vec![0.0f32; n * fan_in];
                for r in 0..n {
                    let di = &dl[r * fan_out..(r + 1) * fan_out];
                    let hi = &acts[li][r * fan_in..(r + 1) * fan_in];
                    let dhi = &mut dh[r * fan_in..(r + 1) * fan_in];
                    for k in 0..fan_in {
                        if hi[k] > 0.0 {
                            let wrow = &w[k * fan_out..(k + 1) * fan_out];
                            let mut acc = 0.0f32;
                            for (wv, dv) in wrow.iter().zip(di) {
                                acc += wv * dv;
                            }
                            dhi[k] = acc;
                        }
                    }
                }
                dl = dh;
            }
        }

        // weight decay on all params: L += 0.5*wd*||p||², g += wd*p
        if weight_decay != 0.0 {
            let mut reg = 0.0f64;
            for (g, &p) in grad.iter_mut().zip(params) {
                *g += weight_decay * p;
                reg += (p as f64) * (p as f64);
            }
            loss += 0.5 * weight_decay as f64 * reg;
        }
        loss as f32
    }

    /// One momentum-SGD half-step in place (params, momentum updated).
    pub fn train_step(
        &self,
        params: &mut [f32],
        momentum: &mut [f32],
        x: &[f32],
        y: &[i32],
        hp: TrainHyper,
        grad_scratch: &mut Vec<f32>,
    ) -> f32 {
        grad_scratch.resize(params.len(), 0.0);
        let loss = self.loss_grad(params, x, y, hp.weight_decay, grad_scratch);
        for ((p, m), &g) in params.iter_mut().zip(momentum.iter_mut()).zip(grad_scratch.iter()) {
            *m = hp.beta * *m + (1.0 - hp.beta) * g;
            *p -= hp.lr * *m;
        }
        loss
    }

    /// (#correct, summed NLL) over an eval set.
    pub fn evaluate(&self, params: &[f32], x: &[f32], y: &[i32]) -> (f64, f64) {
        let n = y.len();
        let mut logp = Vec::new();
        self.forward(params, x, n, &mut logp);
        let c = self.classes;
        let mut correct = 0.0;
        let mut loss = 0.0;
        for r in 0..n {
            let row = &logp[r * c..(r + 1) * c];
            let mut best = 0usize;
            for j in 1..c {
                if row[j] > row[best] {
                    best = j;
                }
            }
            if best as i32 == y[r] {
                correct += 1.0;
            }
            loss -= row[y[r] as usize] as f64;
        }
        (correct, loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> MlpSpec {
        MlpSpec::by_name("mlp_tiny").unwrap()
    }

    #[test]
    fn param_counts_match_python() {
        // values asserted against model.param_count in the pytest suite
        assert_eq!(tiny().param_count(), 16 * 16 + 16 + 16 * 4 + 4);
        assert_eq!(
            MlpSpec::by_name("mlp_mnistlike").unwrap().param_count(),
            64 * 64 + 64 + 64 * 10 + 10
        );
        assert_eq!(
            MlpSpec::by_name("mlp_cifarlike").unwrap().param_count(),
            96 * 128 + 128 + 128 * 64 + 64 + 64 * 10 + 10
        );
        assert_eq!(
            MlpSpec::by_name("mlp_femnistlike").unwrap().param_count(),
            64 * 128 + 128 + 128 * 62 + 62
        );
    }

    #[test]
    fn forward_rows_are_log_probs() {
        let spec = tiny();
        let params = spec.init_native(0);
        let mut rng = Rng::new(1);
        let n = 5;
        let x: Vec<f32> = (0..n * spec.din).map(|_| rng.gaussian32(0.0, 1.0)).collect();
        let mut logp = Vec::new();
        spec.forward(&params, &x, n, &mut logp);
        assert_eq!(logp.len(), n * spec.classes);
        for r in 0..n {
            let s: f64 = logp[r * spec.classes..(r + 1) * spec.classes]
                .iter()
                .map(|&v| (v as f64).exp())
                .sum();
            assert!((s - 1.0).abs() < 1e-5, "row {r} sums to {s}");
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let spec = tiny();
        let mut params = spec.init_native(2);
        let mut rng = Rng::new(3);
        let n = 4;
        let x: Vec<f32> = (0..n * spec.din).map(|_| rng.gaussian32(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..n).map(|_| rng.index(spec.classes) as i32).collect();
        let wd = 1e-3f32;
        let mut grad = vec![0.0f32; params.len()];
        spec.loss_grad(&params, &x, &y, wd, &mut grad);
        let mut scratch = vec![0.0f32; params.len()];
        for probe in 0..10 {
            let idx = (probe * 37) % params.len();
            let eps = 1e-3f32;
            let orig = params[idx];
            params[idx] = orig + eps;
            let f1 = spec.loss_grad(&params, &x, &y, wd, &mut scratch);
            params[idx] = orig - eps;
            let f0 = spec.loss_grad(&params, &x, &y, wd, &mut scratch);
            params[idx] = orig;
            let fd = (f1 - f0) / (2.0 * eps);
            assert!(
                (fd - grad[idx]).abs() < 2e-2,
                "idx={idx} fd={fd} grad={}",
                grad[idx]
            );
        }
    }

    #[test]
    fn training_reduces_loss() {
        let spec = tiny();
        let mut params = spec.init_native(4);
        let mut momentum = vec![0.0f32; params.len()];
        let task = crate::data::synth::TaskKind::Tiny.spec().instantiate(5);
        let data = task.sample_uniform(64, &mut Rng::new(5));
        let hp = TrainHyper {
            lr: 0.2,
            beta: 0.9,
            weight_decay: 0.0,
        };
        let mut scratch = Vec::new();
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..80 {
            let loss = spec.train_step(&mut params, &mut momentum, &data.x, &data.y, hp, &mut scratch);
            if step == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < first * 0.6, "first={first} last={last}");
    }

    #[test]
    fn momentum_semantics_match_paper() {
        // m1 = (1-beta) g when m0 = 0; x1 = x0 - lr m1
        let spec = tiny();
        let params0 = spec.init_native(6);
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..2 * spec.din).map(|_| rng.gaussian32(0.0, 1.0)).collect();
        let y = vec![0i32, 1];
        let mut grad = vec![0.0f32; params0.len()];
        spec.loss_grad(&params0, &x, &y, 0.0, &mut grad);
        let mut params = params0.clone();
        let mut momentum = vec![0.0f32; params.len()];
        let hp = TrainHyper {
            lr: 0.1,
            beta: 0.9,
            weight_decay: 0.0,
        };
        let mut scratch = Vec::new();
        spec.train_step(&mut params, &mut momentum, &x, &y, hp, &mut scratch);
        for i in 0..params.len() {
            let m1 = 0.1 * grad[i];
            assert!((momentum[i] - m1).abs() < 1e-6);
            assert!((params[i] - (params0[i] - 0.1 * m1)).abs() < 1e-6);
        }
    }

    #[test]
    fn evaluate_counts_correct() {
        let spec = tiny();
        let params = spec.init_native(8);
        let task = crate::data::synth::TaskKind::Tiny.spec().instantiate(9);
        let data = task.sample_uniform(40, &mut Rng::new(9));
        let (correct, loss) = spec.evaluate(&params, &data.x, &data.y);
        assert!((0.0..=40.0).contains(&correct));
        assert!(loss > 0.0);
    }

    #[test]
    fn training_reaches_high_accuracy_on_separable_task() {
        let spec = tiny();
        let mut params = spec.init_native(10);
        let mut momentum = vec![0.0f32; params.len()];
        let task = crate::data::synth::TaskKind::Tiny.spec().instantiate(11);
        let train = task.sample_uniform(256, &mut Rng::new(11));
        let test = task.sample_uniform(128, &mut Rng::new(12));
        let hp = TrainHyper {
            lr: 0.3,
            beta: 0.9,
            weight_decay: 1e-4,
        };
        let mut scratch = Vec::new();
        for _ in 0..150 {
            spec.train_step(&mut params, &mut momentum, &train.x, &train.y, hp, &mut scratch);
        }
        let (correct, _) = spec.evaluate(&params, &test.x, &test.y);
        let acc = correct / 128.0;
        assert!(acc > 0.8, "acc={acc}");
    }
}
