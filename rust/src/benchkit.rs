//! Micro/throughput benchmark harness (criterion is not in the offline
//! crate set). Warmup + timed iterations, robust summary statistics, and
//! criterion-style one-line reports. Used by every target in
//! `rust/benches/`.

// Timing is this module's whole job; the rpel-lint wall-clock rule scopes
// to the deterministic modules and does not cover the bench harness.
#![allow(clippy::disallowed_methods)]

use crate::util::stats::{self, Summary};
use std::time::Instant;

/// One benchmark's timing results.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// nanoseconds per iteration, one entry per timed sample
    pub samples_ns: Vec<f64>,
    pub summary: Summary,
    /// optional throughput denominator (items per iteration)
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.summary.mean
    }

    /// Human units.
    fn fmt_ns(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }

    pub fn report(&self) -> String {
        let mut line = format!(
            "{:<44} {:>12}/iter  (p50 {:>10}, p95 {:>10}, n={})",
            self.name,
            Self::fmt_ns(self.summary.mean),
            Self::fmt_ns(self.summary.p50),
            Self::fmt_ns(self.summary.p95),
            self.summary.n,
        );
        if let Some(items) = self.items_per_iter {
            let per_sec = items / (self.summary.mean / 1e9);
            line.push_str(&format!("  [{:.2e} items/s]", per_sec));
        }
        line
    }
}

/// Bench runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub samples: usize,
    /// iterations batched per sample (amortizes clock overhead)
    pub iters_per_sample: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            samples: 12,
            iters_per_sample: 1,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            samples: 5,
            iters_per_sample: 1,
        }
    }

    /// Time `f`, returning per-iteration stats. The closure should return
    /// something observable to defeat dead-code elimination; its value is
    /// black-boxed.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                black_box(f());
            }
            let ns = t0.elapsed().as_nanos() as f64 / self.iters_per_sample as f64;
            samples.push(ns);
        }
        let summary = stats::summarize(&samples);
        BenchResult {
            name: name.to_string(),
            samples_ns: samples,
            summary,
            items_per_iter: None,
        }
    }

    /// Like [`run`], annotating throughput (`items` processed per iter).
    pub fn run_throughput<T>(
        &self,
        name: &str,
        items: f64,
        f: impl FnMut() -> T,
    ) -> BenchResult {
        let mut r = self.run(name, f);
        r.items_per_iter = Some(items);
        r
    }
}

/// Identity function the optimizer cannot see through.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header the way the bench binaries format output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sanity() {
        let b = Bencher::quick();
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.mean_ns() > 0.0);
        assert_eq!(r.samples_ns.len(), 5);
    }

    #[test]
    fn longer_work_times_longer() {
        let b = Bencher {
            warmup_iters: 1,
            samples: 8,
            iters_per_sample: 4,
        };
        // bounds go through black_box so release builds cannot
        // constant-fold the loops away
        let short = b.run("short", || {
            (0..black_box(100u64)).fold(0u64, |a, x| a ^ x.wrapping_mul(31))
        });
        let long = b.run("long", || {
            (0..black_box(1_000_000u64)).fold(0u64, |a, x| a ^ x.wrapping_mul(31))
        });
        assert!(long.mean_ns() > short.mean_ns());
    }

    #[test]
    fn report_formats() {
        let b = Bencher::quick();
        let r = b.run_throughput("fmt", 1000.0, || black_box(1 + 1));
        let line = r.report();
        assert!(line.contains("fmt"));
        assert!(line.contains("items/s"));
    }
}
