//! Regression tests for the delivered-message ledger (the paper's
//! headline axis). The nominal budget `messages_per_round` is a constant
//! per config, but what honest nodes actually receive diverges exactly
//! in the adversarial regimes the paper characterizes:
//!
//! * **DoS** (epidemic pull): Byzantine peers withhold every response —
//!   delivered = Σ_i (s − |S_i^t ∩ B|), recomputed here independently
//!   from the public counter-keyed pull sampler;
//! * **push flooding**: pushes to Byzantine recipients are wasted, while
//!   every Byzantine sender floods all honest nodes — delivered =
//!   honest→honest pushes + h·b, recomputed from the PUSH streams;
//! * **push + DoS**: the flood is withheld too — honest→honest only.
//!
//! The old engine credited `messages_per_round()` every round no matter
//! what arrived; these tests pin both ledgers.

use rpel::config::{ExperimentConfig, Topology};
use rpel::coordinator::{PullSampler, Trainer};
use rpel::data::TaskKind;
use rpel::util::rng::{stream_tag, Rng};
use std::collections::HashSet;

const N: usize = 12;
const B: usize = 3;
const S: usize = 5;
const ROUNDS: usize = 6;

fn base_cfg(attack: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = format!("msg_accounting_{attack}");
    cfg.n = N;
    cfg.b = B;
    cfg.topology = Topology::Epidemic { s: S };
    cfg.bhat = Some(2);
    cfg.attack = rpel::attacks::AttackKind::parse(attack).unwrap();
    cfg.rounds = ROUNDS;
    cfg.batch = 8;
    cfg.samples_per_node = 32;
    cfg.test_samples = 64;
    cfg.eval_every = 100;
    cfg.threads = 1;
    cfg
}

fn byzantine_set(cfg: &ExperimentConfig) -> HashSet<usize> {
    // a second construction from the same config reproduces the same
    // adversary placement (all construction randomness is seed-derived)
    Trainer::from_config(cfg)
        .unwrap()
        .byzantine_ids()
        .into_iter()
        .collect()
}

#[test]
fn dos_delivered_matches_independent_pull_recomputation() {
    let cfg = base_cfg("dos");
    let byz = byzantine_set(&cfg);
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();

    // nominal budget is untouched by withholding
    assert_eq!(hist.messages_per_round, N * S);
    assert_eq!(hist.total_messages, N * S * ROUNDS);

    // delivered = per victim, the honest members of its pull set
    let sampler = PullSampler::new(N, S);
    assert_eq!(hist.delivered_per_round.len(), ROUNDS);
    for round in 0..ROUNDS {
        let mut expect = 0usize;
        for id in 0..N {
            if byz.contains(&id) {
                continue;
            }
            let pulled = sampler.sample_at(cfg.seed, round, id);
            expect += pulled.iter().filter(|p| !byz.contains(p)).count();
        }
        assert_eq!(
            hist.delivered_per_round[round], expect,
            "round {round}: delivered mismatch"
        );
    }
    assert_eq!(
        hist.total_delivered,
        hist.delivered_per_round.iter().sum::<usize>()
    );
    assert!(
        hist.total_delivered < hist.total_messages,
        "withholding must show up in the ledger"
    );
}

#[test]
fn responding_adversary_delivers_full_pull_sets() {
    // under ALIE every pulled peer responds (maliciously or not):
    // exactly h·s rows arrive per round; the nominal budget additionally
    // counts the Byzantine nodes' own pulls (b·s)
    let cfg = base_cfg("alie");
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
    let h = N - B;
    assert!(hist.delivered_per_round.iter().all(|&x| x == h * S));
    assert_eq!(hist.total_delivered, h * S * ROUNDS);
    assert_eq!(hist.total_messages, N * S * ROUNDS);
}

/// Independent recomputation of one round's honest→honest push count
/// from the public `(seed, round, sender, PUSH)` streams.
fn honest_push_deliveries(cfg: &ExperimentConfig, byz: &HashSet<usize>, round: usize) -> usize {
    let mut delivered = 0usize;
    for id in 0..cfg.n {
        if byz.contains(&id) {
            continue;
        }
        let mut rng = Rng::stream(cfg.seed, round as u64, id as u64, stream_tag::PUSH);
        delivered += rng
            .sample_distinct_excluding(cfg.n, S, id)
            .iter()
            .filter(|dest| !byz.contains(dest))
            .count();
    }
    delivered
}

#[test]
fn push_flood_ledger_counts_wasted_pushes_and_flooding() {
    let mut cfg = base_cfg("sf");
    cfg.topology = Topology::EpidemicPush { s: S };
    let byz = byzantine_set(&cfg);
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
    let h = N - B;

    // nominal: honest pushes + the Byzantine flood
    assert_eq!(hist.messages_per_round, h * S + B * h);

    for round in 0..ROUNDS {
        // delivered: honest→honest pushes (pushes to Byzantine
        // recipients are wasted) + each Byzantine node flooding every
        // honest node once
        let expect = honest_push_deliveries(&cfg, &byz, round) + h * B;
        assert_eq!(
            hist.delivered_per_round[round], expect,
            "round {round}: push ledger mismatch"
        );
    }
}

#[test]
fn push_dos_withholds_the_flood_too() {
    let mut cfg = base_cfg("dos");
    cfg.topology = Topology::EpidemicPush { s: S };
    let byz = byzantine_set(&cfg);
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();

    for round in 0..ROUNDS {
        let expect = honest_push_deliveries(&cfg, &byz, round);
        assert_eq!(
            hist.delivered_per_round[round], expect,
            "round {round}: push+DoS ledger mismatch"
        );
    }
    assert!(hist.total_delivered < hist.total_messages);
}

#[test]
fn gossip_dos_drops_byzantine_edges_from_the_ledger() {
    use rpel::aggregation::gossip::GossipRuleKind;
    use rpel::config::RuleChoice;

    let mut cfg = base_cfg("dos");
    cfg.topology = Topology::FixedGraph { edges: 24 };
    cfg.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();

    // the graph is fixed, so the delivered count is round-constant and
    // strictly below the nominal 2·|E| budget (Byzantine endpoints)
    assert_eq!(hist.messages_per_round, 48);
    let first = hist.delivered_per_round[0];
    assert!(hist.delivered_per_round.iter().all(|&x| x == first));
    assert!(first < 48);
}
