//! Regression tests for the delivered-message ledger (the paper's
//! headline axis). The nominal budget `messages_per_round` is a constant
//! per config, but what honest nodes actually receive diverges exactly
//! in the adversarial regimes the paper characterizes:
//!
//! * **DoS** (epidemic pull): Byzantine peers withhold every response —
//!   delivered = Σ_i (s − |S_i^t ∩ B|), recomputed here independently
//!   from the public counter-keyed pull sampler;
//! * **push flooding**: pushes to Byzantine recipients are wasted, while
//!   every Byzantine sender floods all honest nodes — delivered =
//!   honest→honest pushes + h·b, recomputed from the PUSH streams;
//! * **push + DoS**: the flood is withheld too — honest→honest only.
//!
//! The old engine credited `messages_per_round()` every round no matter
//! what arrived; these tests pin both ledgers.
//!
//! The **bytes-on-the-wire ledger** (multi-process engine) is pinned the
//! same way: coordinator-broadcast and peer-served bytes per round are
//! recomputed independently from the public routing table (counter-keyed
//! pull streams), so the socket path provably ships no committed row the
//! routing table doesn't require — and measurably drops the per-worker
//! coordinator traffic from O(h·d) to O(s·d + routing table).
//!
//! The **codec ledger** (`wire_raw_bytes_per_round` /
//! `wire_encoded_bytes_per_round`) is pinned byte-exactly the same way:
//! raw is 4·d per shipped row, encoded is the compression stride, over
//! exactly the Snapshot + PullReply rows the routing table requires.

// Test/bench code may time things, read the environment, and build
// scratch hash tables (clippy.toml's disallowed lists guard src only;
// the rpel-lint pass likewise skips test code).
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use rpel::attacks::HonestDigest;
use rpel::config::{ExperimentConfig, Topology, TransportKind};
use rpel::coordinator::{PullSampler, Trainer};
use rpel::data::TaskKind;
use rpel::util::rng::{stream_tag, Rng};
use rpel::wire::proto;
use std::collections::HashSet;

const N: usize = 12;
const B: usize = 3;
const S: usize = 5;
const ROUNDS: usize = 6;

fn base_cfg(attack: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = format!("msg_accounting_{attack}");
    cfg.n = N;
    cfg.b = B;
    cfg.topology = Topology::Epidemic { s: S };
    cfg.bhat = Some(2);
    cfg.attack = rpel::attacks::AttackKind::parse(attack).unwrap();
    cfg.rounds = ROUNDS;
    cfg.batch = 8;
    cfg.samples_per_node = 32;
    cfg.test_samples = 64;
    cfg.eval_every = 100;
    cfg.threads = 1;
    cfg
}

fn byzantine_set(cfg: &ExperimentConfig) -> HashSet<usize> {
    // a second construction from the same config reproduces the same
    // adversary placement (all construction randomness is seed-derived)
    Trainer::from_config(cfg)
        .unwrap()
        .byzantine_ids()
        .into_iter()
        .collect()
}

#[test]
fn dos_delivered_matches_independent_pull_recomputation() {
    let cfg = base_cfg("dos");
    let byz = byzantine_set(&cfg);
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();

    // nominal budget is untouched by withholding
    assert_eq!(hist.messages_per_round, N * S);
    assert_eq!(hist.total_messages, N * S * ROUNDS);

    // delivered = per victim, the honest members of its pull set
    let sampler = PullSampler::new(N, S);
    assert_eq!(hist.delivered_per_round.len(), ROUNDS);
    for round in 0..ROUNDS {
        let mut expect = 0usize;
        for id in 0..N {
            if byz.contains(&id) {
                continue;
            }
            let pulled = sampler.sample_at(cfg.seed, round, id);
            expect += pulled.iter().filter(|p| !byz.contains(p)).count();
        }
        assert_eq!(
            hist.delivered_per_round[round], expect,
            "round {round}: delivered mismatch"
        );
    }
    assert_eq!(
        hist.total_delivered,
        hist.delivered_per_round.iter().sum::<usize>()
    );
    assert!(
        hist.total_delivered < hist.total_messages,
        "withholding must show up in the ledger"
    );
}

#[test]
fn responding_adversary_delivers_full_pull_sets() {
    // under ALIE every pulled peer responds (maliciously or not):
    // exactly h·s rows arrive per round; the nominal budget additionally
    // counts the Byzantine nodes' own pulls (b·s)
    let cfg = base_cfg("alie");
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
    let h = N - B;
    assert!(hist.delivered_per_round.iter().all(|&x| x == h * S));
    assert_eq!(hist.total_delivered, h * S * ROUNDS);
    assert_eq!(hist.total_messages, N * S * ROUNDS);
}

/// Independent recomputation of one round's honest→honest push count
/// from the public `(seed, round, sender, PUSH)` streams.
fn honest_push_deliveries(cfg: &ExperimentConfig, byz: &HashSet<usize>, round: usize) -> usize {
    let mut delivered = 0usize;
    for id in 0..cfg.n {
        if byz.contains(&id) {
            continue;
        }
        let mut rng = Rng::stream(cfg.seed, round as u64, id as u64, stream_tag::PUSH);
        delivered += rng
            .sample_distinct_excluding(cfg.n, S, id)
            .iter()
            .filter(|dest| !byz.contains(dest))
            .count();
    }
    delivered
}

#[test]
fn push_flood_ledger_counts_wasted_pushes_and_flooding() {
    let mut cfg = base_cfg("sf");
    cfg.topology = Topology::EpidemicPush { s: S };
    let byz = byzantine_set(&cfg);
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
    let h = N - B;

    // nominal: honest pushes + the Byzantine flood
    assert_eq!(hist.messages_per_round, h * S + B * h);

    for round in 0..ROUNDS {
        // delivered: honest→honest pushes (pushes to Byzantine
        // recipients are wasted) + each Byzantine node flooding every
        // honest node once
        let expect = honest_push_deliveries(&cfg, &byz, round) + h * B;
        assert_eq!(
            hist.delivered_per_round[round], expect,
            "round {round}: push ledger mismatch"
        );
    }
}

#[test]
fn push_dos_withholds_the_flood_too() {
    let mut cfg = base_cfg("dos");
    cfg.topology = Topology::EpidemicPush { s: S };
    let byz = byzantine_set(&cfg);
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();

    for round in 0..ROUNDS {
        let expect = honest_push_deliveries(&cfg, &byz, round);
        assert_eq!(
            hist.delivered_per_round[round], expect,
            "round {round}: push+DoS ledger mismatch"
        );
    }
    assert!(hist.total_delivered < hist.total_messages);
}

// ---------------------------------------------------------------------------
// Bytes-on-the-wire ledger (multi-process engine)
// ---------------------------------------------------------------------------

fn enable_worker_bin() {
    rpel::coordinator::proc::set_worker_bin(env!("CARGO_BIN_EXE_rpel"));
}

/// Contiguous balanced partition — mirrors the engine's canonical split.
fn ranges_of(h: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, h.max(1));
    let (base, extra) = (h / parts, h % parts);
    let mut out = Vec::new();
    let mut start = 0usize;
    for k in 0..parts {
        let len = base + usize::from(k < extra);
        out.push((start, len));
        start += len;
    }
    out
}

/// id → honest index for the non-Byzantine nodes, ascending.
fn node_of_map(n: usize, byz: &HashSet<usize>) -> Vec<usize> {
    let mut node_of = vec![usize::MAX; n];
    let mut h = 0usize;
    for id in 0..n {
        if !byz.contains(&id) {
            node_of[id] = h;
            h += 1;
        }
    }
    node_of
}

#[test]
fn in_process_runs_report_a_zero_wire_ledger() {
    let cfg = base_cfg("alie");
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(hist.wire_coord_out_per_round, vec![0; ROUNDS]);
    assert_eq!(hist.wire_coord_in_per_round, vec![0; ROUNDS]);
    assert_eq!(hist.wire_peer_per_round, vec![0; ROUNDS]);
    // the codec ledgers measure the multi-process row payloads only
    assert_eq!(hist.wire_raw_bytes_per_round, vec![0; ROUNDS]);
    assert_eq!(hist.wire_encoded_bytes_per_round, vec![0; ROUNDS]);
}

/// The socket path's per-round bytes — coordinator-out, coordinator-in,
/// and peer-served — must equal an **independent recomputation from the
/// routing table**. Byte-exact equality is the "no unrequired rows"
/// assertion: a single committed row shipped beyond what the routing
/// table requires would shift the count by 4·d+ bytes.
#[test]
fn socket_wire_ledger_matches_routing_table_recomputation() {
    enable_worker_bin();
    let mut cfg = base_cfg("alie");
    cfg.procs = 2;
    cfg.transport = TransportKind::Socket;
    cfg.name = "wire_ledger_socket".into();

    let byz = byzantine_set(&{
        let mut c = cfg.clone();
        c.procs = 1; // placement is seed-derived; skip the worker spawns
        c
    });
    let node_of = node_of_map(N, &byz);
    let h = N - B;
    // d from an in-process twin (identical world construction)
    let d = {
        let mut c = cfg.clone();
        c.procs = 1;
        let t = Trainer::from_config(&c).unwrap();
        t.params_of(0).len()
    };

    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(hist.wire_coord_out_per_round.len(), ROUNDS);

    let ranges = ranges_of(h, cfg.procs);
    let sampler = PullSampler::new(N, S);
    let digest_shape = HonestDigest::new(d); // ledger compares lengths only
    let zero_row = vec![0.0f32; d];
    // (worker, owner) pairs that already paid the one-time PeerHello
    let mut connected: HashSet<(usize, usize)> = HashSet::new();

    for round in 0..ROUNDS {
        // the public routing table: per victim (ascending honest order),
        // the ordered pull set from the counter-keyed stream
        let mut routes: Vec<Vec<usize>> = Vec::with_capacity(h);
        for id in 0..N {
            if !byz.contains(&id) {
                routes.push(sampler.sample_at(cfg.seed, round, id));
            }
        }

        let mut expect_out = 0usize;
        let mut expect_in = 0usize;
        let mut expect_peer = 0usize;
        for (w, &(start, len)) in ranges.iter().enumerate() {
            // coordinator → worker: HalfStep + AggregateRouted
            expect_out += 4 + proto::encode_half_step(round as u64).len();
            let slice: Vec<Vec<u32>> = routes[start..start + len]
                .iter()
                .map(|per| per.iter().map(|&p| p as u32).collect())
                .collect();
            expect_out +=
                4 + proto::encode_aggregate_routed(round as u64, &digest_shape, &slice).len();

            // worker → coordinator: Snapshot + RoundDone (shape-only)
            let rows: Vec<Vec<f32>> = vec![zero_row.clone(); len];
            expect_in +=
                4 + proto::encode_snapshot(round as u64, &vec![0.0f64; len], &rows).len();
            expect_in += 4
                + proto::encode_round_done(round as u64, &vec![0; len], &vec![0; len], 0, 0, &rows)
                    .len();

            // peer-served: per owner, the sorted unique off-shard honest
            // rows this worker's victims require — nothing more
            let mut need: Vec<Vec<u32>> = vec![Vec::new(); ranges.len()];
            for per in &routes[start..start + len] {
                for &p in per {
                    if byz.contains(&p) {
                        continue;
                    }
                    let hi = node_of[p];
                    if hi >= start && hi < start + len {
                        continue;
                    }
                    let owner = ranges
                        .iter()
                        .position(|&(s, l)| hi >= s && hi < s + l)
                        .unwrap();
                    need[owner].push(hi as u32);
                }
            }
            for (owner, mut rows_idx) in need.into_iter().enumerate() {
                if rows_idx.is_empty() {
                    continue;
                }
                rows_idx.sort_unstable();
                rows_idx.dedup();
                if connected.insert((w, owner)) {
                    expect_peer += 4 + proto::encode_peer_hello(w as u32, 0, "").len();
                }
                expect_peer += 4 + proto::encode_pull_request(round as u64, &rows_idx).len();
                let reply_rows: Vec<Vec<f32>> = vec![zero_row.clone(); rows_idx.len()];
                expect_peer += 4 + proto::encode_pull_reply(round as u64, &reply_rows).len();
            }
        }

        assert_eq!(
            hist.wire_coord_out_per_round[round], expect_out,
            "round {round}: coordinator→worker bytes"
        );
        assert_eq!(
            hist.wire_coord_in_per_round[round], expect_in,
            "round {round}: worker→coordinator bytes"
        );
        assert_eq!(
            hist.wire_peer_per_round[round], expect_peer,
            "round {round}: peer-served bytes (the no-unrequired-rows pin)"
        );
    }

    // at compression = none the row codec is the identity: the raw and
    // encoded ledgers must agree byte for byte, and both must be live
    assert_eq!(
        hist.wire_raw_bytes_per_round,
        hist.wire_encoded_bytes_per_round
    );
    assert!(hist.wire_raw_bytes_per_round.iter().all(|&x| x > 0));
}

/// Byte-exact pin of the q8 codec ledgers: raw counts 4·d per row and
/// encoded (d+4) per row, over exactly the rows the protocol ships —
/// each worker's Snapshot block (its shard residents) plus the sorted
/// deduped off-shard honest rows its victims pull. A single extra or
/// missing row, or one mis-sized segment, shifts the sum.
#[test]
fn q8_codec_ledger_matches_byte_exact_recomputation() {
    use rpel::wire::codec::{block_bytes, Compression};

    enable_worker_bin();
    let mut cfg = base_cfg("alie");
    cfg.procs = 2;
    cfg.transport = TransportKind::Socket;
    cfg.compression = Compression::Q8;
    cfg.name = "codec_ledger_q8".into();

    let byz = byzantine_set(&{
        let mut c = cfg.clone();
        c.procs = 1; // placement is seed-derived; skip the worker spawns
        c
    });
    let node_of = node_of_map(N, &byz);
    let h = N - B;
    let d = {
        let mut c = cfg.clone();
        c.procs = 1;
        let t = Trainer::from_config(&c).unwrap();
        t.params_of(0).len()
    };

    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(hist.wire_raw_bytes_per_round.len(), ROUNDS);
    assert_eq!(hist.wire_encoded_bytes_per_round.len(), ROUNDS);

    let ranges = ranges_of(h, cfg.procs);
    let sampler = PullSampler::new(N, S);
    for round in 0..ROUNDS {
        let mut routes: Vec<Vec<usize>> = Vec::with_capacity(h);
        for id in 0..N {
            if !byz.contains(&id) {
                routes.push(sampler.sample_at(cfg.seed, round, id));
            }
        }
        // rows on the wire this round: Snapshot residents + deduped
        // off-shard pulls, per worker
        let mut rows_total = 0usize;
        for &(start, len) in &ranges {
            rows_total += len;
            let mut pulled: Vec<usize> = Vec::new();
            for per in &routes[start..start + len] {
                for &p in per {
                    if byz.contains(&p) {
                        continue;
                    }
                    let hi = node_of[p];
                    if hi < start || hi >= start + len {
                        pulled.push(hi);
                    }
                }
            }
            pulled.sort_unstable();
            pulled.dedup();
            rows_total += pulled.len();
        }
        let expect_raw = block_bytes(Compression::None, rows_total, d);
        let expect_enc = block_bytes(Compression::Q8, rows_total, d);
        assert_eq!(
            hist.wire_raw_bytes_per_round[round], expect_raw,
            "round {round}: raw row-payload bytes"
        );
        assert_eq!(
            hist.wire_encoded_bytes_per_round[round], expect_enc,
            "round {round}: q8 row-payload bytes"
        );
    }

    // the headline ratio at model scale: one raw f32 row at d = 1000 is
    // 4000 bytes, the q8 row is 1004 — a ≥3× diet (4d / (d+4) ≈ 3.98)
    let d_big = 1000;
    assert!(
        block_bytes(Compression::None, 1, d_big) >= 3 * block_bytes(Compression::Q8, 1, d_big),
        "q8 must shrink rows by at least 3x at d >= 1000"
    );
}

/// The measured O(h·d) → O(s·d + routing table) reduction: at h ≫ s the
/// socket path's coordinator-broadcast bytes must be a small fraction of
/// the pipe broadcast for the identical experiment.
#[test]
fn socket_coordinator_traffic_beats_pipe_broadcast() {
    enable_worker_bin();
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = "wire_ledger_ratio".into();
    cfg.n = 40;
    cfg.b = 4;
    cfg.topology = Topology::Epidemic { s: 5 };
    cfg.bhat = Some(2);
    cfg.attack = rpel::attacks::AttackKind::parse("alie").unwrap();
    cfg.rounds = 2;
    cfg.batch = 8;
    cfg.samples_per_node = 16;
    cfg.test_samples = 32;
    cfg.eval_every = 100;
    cfg.threads = 1;
    cfg.procs = 2;

    let mut pipe_cfg = cfg.clone();
    pipe_cfg.transport = TransportKind::Pipe;
    let pipe = Trainer::from_config(&pipe_cfg).unwrap().run().unwrap();

    let mut sock_cfg = cfg.clone();
    sock_cfg.transport = TransportKind::Socket;
    let sock = Trainer::from_config(&sock_cfg).unwrap().run().unwrap();

    // identical training outcome, different wire footprint
    assert_eq!(pipe.train_loss, sock.train_loss);
    for round in 0..cfg.rounds {
        let (p, s) = (
            pipe.wire_coord_out_per_round[round],
            sock.wire_coord_out_per_round[round],
        );
        assert!(p > 0 && s > 0, "round {round}: ledgers must be recorded");
        assert!(
            s * 3 < p,
            "round {round}: socket coordinator traffic {s} should be well \
             below the pipe broadcast {p} (h=36 ≫ s=5)"
        );
        // the rows moved peer-to-peer instead
        assert!(sock.wire_peer_per_round[round] > 0);
        assert_eq!(pipe.wire_peer_per_round[round], 0);
    }
}

#[test]
fn gossip_dos_drops_byzantine_edges_from_the_ledger() {
    use rpel::aggregation::gossip::GossipRuleKind;
    use rpel::config::RuleChoice;

    let mut cfg = base_cfg("dos");
    cfg.topology = Topology::FixedGraph { edges: 24 };
    cfg.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();

    // the graph is fixed, so the delivered count is round-constant and
    // strictly below the nominal 2·|E| budget (Byzantine endpoints)
    assert_eq!(hist.messages_per_round, 48);
    let first = hist.delivered_per_round[0];
    assert!(hist.delivered_per_round.iter().all(|&x| x == first));
    assert!(first < 48);
}
