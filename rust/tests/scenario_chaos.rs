//! Scenario-grade chaos tier: every named asynchrony scenario from
//! [`rpel::testkit::scenario`] is driven end to end.
//!
//! * scenarios round-trip through TOML **exactly** — both at the tier
//!   level and embedded in a full experiment config;
//! * a partition that heals has an *exactly* deterministic schedule
//!   (participation, staleness histogram, virtual close are pinned
//!   value-for-value) and the run still converges;
//! * crash/rejoin churn matches an independent twin built from the
//!   public `(seed, round, node, CHURN)` streams, and nodes genuinely
//!   recover (fresh → down → fresh again);
//! * a worker killed forever under an async scenario surfaces an
//!   actionable error naming the worker and its honest range — never a
//!   hang;
//! * a rejoining worker serves pulls again: `PeerClient::reset_conns`
//!   re-dials and re-handshakes, including across a full server restart
//!   on the same address;
//! * and a source lint: the deterministic modules (`coordinator/`,
//!   `aggregation/`, `sampling/`) contain no wall-clock reads outside
//!   explicitly `lint: wall-clock-exempt`-marked lines — the virtual
//!   clock is the only clock.

// Test/bench code may time things, read the environment, and build
// scratch hash tables (clippy.toml's disallowed lists guard src only;
// the rpel-lint pass likewise skips test code).
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use rpel::attacks::AttackKind;
use rpel::config::file::{from_toml_str, to_toml_str};
use rpel::config::{ExperimentConfig, Topology};
use rpel::coordinator::peer::{PeerClient, RowServer};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::testkit::scenario::Scenario;
use rpel::util::rng::{stream_tag, Rng};
use rpel::wire::codec::RowCodec;
use rpel::wire::proto::PeerEntry;
use rpel::wire::transport::{Listener, RetryPolicy, SockAddr};
use std::path::Path;

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = "chaos".into();
    cfg.n = 12;
    cfg.b = 2;
    cfg.topology = Topology::Epidemic { s: 6 };
    cfg.bhat = Some(2);
    cfg.attack = AttackKind::Alie;
    cfg.rounds = 10;
    cfg.batch = 8;
    cfg.samples_per_node = 48;
    cfg.test_samples = 96;
    cfg.eval_every = 100;
    cfg
}

fn enable_worker_bin() {
    rpel::coordinator::proc::set_worker_bin(env!("CARGO_BIN_EXE_rpel"));
}

// ---------------------------------------------------------------------------
// TOML round-trips
// ---------------------------------------------------------------------------

#[test]
fn every_scenario_round_trips_toml_exactly() {
    let all = Scenario::all();
    assert!(!all.is_empty());
    for s in all {
        // tier level: a scenario file reparses to the identical scenario
        let text = s.to_toml_str();
        let back = Scenario::from_toml_str(&text)
            .unwrap_or_else(|e| panic!("{}: reparse failed: {e}\n---\n{text}", s.name));
        assert_eq!(back, s, "{}: scenario round trip\n---\n{text}", s.name);

        // and embedded in a full experiment config: the same [async]
        // section the coordinator ships to shard workers
        let mut cfg = base_cfg();
        s.apply(&mut cfg)
            .unwrap_or_else(|e| panic!("{}: apply failed: {e}", s.name));
        let doc = to_toml_str(&cfg);
        assert!(
            doc.contains("[async]"),
            "{}: config TOML must carry the async section:\n{doc}",
            s.name
        );
        let back = from_toml_str(&doc)
            .unwrap_or_else(|e| panic!("{}: config reparse failed: {e}\n---\n{doc}", s.name));
        assert_eq!(back, cfg, "{}: full-config round trip", s.name);
    }
}

// ---------------------------------------------------------------------------
// partition_heal: an exactly deterministic schedule, and convergence
// ---------------------------------------------------------------------------

#[test]
fn partition_heal_schedule_is_exact_and_the_run_converges() {
    // quorum 6, partition takes honest nodes 0..3 out of rounds 2..5
    // (1-based), constant latency 1.0 — every ledger entry is derivable
    // by hand, so pin all of them exactly
    let mut cfg = base_cfg();
    Scenario::named("partition_heal").unwrap().apply(&mut cfg).unwrap();
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
    let h = (cfg.n - cfg.b) as u32; // 10

    assert_eq!(
        hist.participation_per_round,
        vec![h, 7, 7, 7, h, h, h, h, h, h],
        "participation must dip to 7 exactly while the partition holds"
    );
    // with constant latency the quorum close is the base latency every
    // round the quorum is met (it is: 7 alive ≥ quorum 6)
    assert_eq!(
        hist.virtual_close_per_round
            .iter()
            .map(|x| x.to_bits())
            .collect::<Vec<_>>(),
        vec![1.0f64.to_bits(); cfg.rounds],
        "virtual close is the constant base latency, bit-exact"
    );
    // the three partitioned nodes age 1, 2, 3 across the window and
    // refresh on heal: hist = [91, 3, 3, 3, 0]
    assert_eq!(hist.staleness_hist, vec![91, 3, 3, 3, 0]);
    assert_eq!(
        hist.staleness_hist.iter().sum::<u64>(),
        h as u64 * cfg.rounds as u64,
        "every (round, node) pair lands in exactly one bucket"
    );

    // heal means the run still trains through the outage
    assert_eq!(hist.train_loss.len(), cfg.rounds);
    assert!(hist.train_loss.iter().all(|l| l.is_finite()));
    assert!(
        hist.train_loss[cfg.rounds - 1] < hist.train_loss[0],
        "loss must still fall across the partition: {:?}",
        hist.train_loss
    );
}

// ---------------------------------------------------------------------------
// crash_recover: churn matches its stream twin, and nodes come back
// ---------------------------------------------------------------------------

/// Independent twin of the churn schedule under **constant** latency:
/// crash coins from the public `(seed, round, node, CHURN)` stream, a
/// crashed node stays down `down_rounds` rounds, every alive node lands
/// exactly at the base latency so freshness == aliveness.
fn churn_twin(cfg: &ExperimentConfig) -> (Vec<u32>, Vec<u64>, Vec<Vec<bool>>) {
    let a = &cfg.asyn;
    let h = cfg.n - cfg.b;
    let cap = a.max_staleness as u64 + 1;
    let mut down_until = vec![0u64; h];
    let mut last_fresh = vec![0u64; h];
    let mut participation = Vec::with_capacity(cfg.rounds);
    let mut hist = vec![0u64; a.max_staleness + 2];
    let mut fresh_rows = Vec::with_capacity(cfg.rounds);
    for round in 1..=cfg.rounds as u64 {
        for i in 0..h {
            let u = Rng::stream(cfg.seed, round, i as u64, stream_tag::CHURN).f64();
            if u < a.crash_prob && round >= down_until[i] {
                down_until[i] = round + a.down_rounds as u64;
            }
        }
        let fresh: Vec<bool> = (0..h).map(|i| round >= down_until[i]).collect();
        for i in 0..h {
            if fresh[i] {
                last_fresh[i] = round;
                hist[0] += 1;
            } else {
                hist[((round - last_fresh[i]).min(cap)) as usize] += 1;
            }
        }
        participation.push(fresh.iter().filter(|&&f| f).count() as u32);
        fresh_rows.push(fresh);
    }
    (participation, hist, fresh_rows)
}

#[test]
fn crash_recover_matches_its_stream_twin_and_nodes_rejoin() {
    let mut cfg = base_cfg();
    Scenario::named("crash_recover").unwrap().apply(&mut cfg).unwrap();
    let mut t = Trainer::from_config(&cfg).unwrap();
    let hist = t.run().unwrap();
    let h = cfg.n - cfg.b;

    let (participation, stale_hist, fresh_rows) = churn_twin(&cfg);
    assert_eq!(hist.participation_per_round, participation, "participation ledger");
    assert_eq!(hist.staleness_hist, stale_hist, "staleness histogram");

    // the seed must actually produce churn, or the twin match is vacuous
    assert!(
        participation.iter().any(|&p| (p as usize) < h),
        "crash_recover produced no crashes: {participation:?}"
    );
    // …and at least one node must come back: fresh, then down, then
    // fresh again — the rejoin path, not a permanent exit
    let recovered = (0..h).any(|i| {
        let mut seen_down_after_fresh = false;
        let mut was_fresh = false;
        for row in &fresh_rows {
            if row[i] && seen_down_after_fresh {
                return true;
            }
            if !row[i] && was_fresh {
                seen_down_after_fresh = true;
            }
            was_fresh = was_fresh || row[i];
        }
        false
    });
    assert!(recovered, "no node ever rejoined: {fresh_rows:?}");

    // the run ends consistent: finite losses, finite final models
    assert!(hist.train_loss.iter().all(|l| l.is_finite()));
    for i in 0..t.honest_count() {
        assert!(
            t.params_of(i).iter().all(|x| x.is_finite()),
            "node {i} ended with non-finite params"
        );
    }
}

// ---------------------------------------------------------------------------
// killed forever: a named error, never a hang
// ---------------------------------------------------------------------------

#[test]
fn killed_worker_under_async_scenario_fails_by_name_never_hangs() {
    enable_worker_bin();
    let mut cfg = base_cfg();
    cfg.name = "chaos_proc_crash".into();
    cfg.rounds = 50;
    cfg.procs = 2;
    cfg.threads = 1;
    Scenario::named("straggler_twopoint").unwrap().apply(&mut cfg).unwrap();

    let mut t = Trainer::from_config(&cfg).unwrap();
    assert_eq!(t.shard_count(), 2);
    t.round(0).expect("healthy async round");

    assert!(t.kill_shard_worker(1), "worker 1 should be killable");
    let mut failure = None;
    for round in 1..cfg.rounds {
        if let Err(e) = t.round(round) {
            failure = Some(format!("{e:#}"));
            break;
        }
    }
    // the worker is gone for good (no rejoin at the process layer): the
    // loop completing at all IS the no-hang assertion
    let msg = failure.expect("rounds must fail after the worker died");
    assert!(
        msg.contains("shard worker 1"),
        "error should name the dead worker: {msg}"
    );
    assert!(
        msg.contains("honest nodes"),
        "error should name the orphaned range: {msg}"
    );
}

// ---------------------------------------------------------------------------
// rejoin: reset_conns re-dials and re-handshakes
// ---------------------------------------------------------------------------

fn two_worker_book(serving: &SockAddr) -> Vec<PeerEntry> {
    vec![
        PeerEntry {
            start: 0,
            len: 5,
            addr: "tcp:127.0.0.1:1".into(), // own range: never dialed
        },
        PeerEntry {
            start: 5,
            len: 2,
            addr: serving.to_string(),
        },
    ]
}

#[test]
fn reset_conns_rehandshakes_and_replays_the_hello_bytes_exactly() {
    let listener = Listener::bind(&SockAddr::Tcp("127.0.0.1:0".into())).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = RowServer::spawn(listener, 1, 5, 2).unwrap();
    server.publish(1, &[vec![1.0f32, 2.0], vec![3.0, 4.0]], None);

    let mut client =
        PeerClient::new(0, 0, RetryPolicy::once(), &two_worker_book(&addr)).unwrap();
    let (rows, d_first) = client.fetch(1, 1, &[5, 6], 2, &RowCodec::none()).unwrap();
    assert_eq!(rows, vec![vec![1.0f32, 2.0], vec![3.0, 4.0]]);

    // warm fetch: the cached connection skips the Hello
    server.publish(2, &[vec![5.0f32, 6.0], vec![7.0, 8.0]], None);
    let (_, d_warm) = client.fetch(2, 1, &[5, 6], 2, &RowCodec::none()).unwrap();
    assert!(
        d_warm < d_first,
        "warm fetch must not re-send the Hello ({d_warm} vs {d_first})"
    );

    // the rejoin path: reset, then the next fetch re-dials and
    // re-identifies — byte-for-byte the same cost as first contact
    client.reset_conns();
    let (rows, d_rejoin) = client.fetch(2, 1, &[5, 6], 2, &RowCodec::none()).unwrap();
    assert_eq!(rows, vec![vec![5.0f32, 6.0], vec![7.0, 8.0]]);
    assert_eq!(
        d_rejoin, d_first,
        "a re-handshake replays exactly the first-contact bytes"
    );
}

#[cfg(unix)]
#[test]
fn restarted_worker_serves_pulls_again_after_reset_conns() {
    // a full crash/rejoin at the transport layer: the serving worker
    // goes away, a new incarnation binds the same address, and only
    // `reset_conns` routes the client to it
    let dir = std::env::temp_dir().join(format!("rpel-chaos-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rejoin.sock");

    let listener = Listener::bind(&SockAddr::Unix(path.clone())).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = RowServer::spawn(listener, 1, 5, 2).unwrap();
    server.publish(1, &[vec![1.0f32], vec![2.0]], None);

    let mut client =
        PeerClient::new(0, 0, RetryPolicy::once(), &two_worker_book(&addr)).unwrap();
    let (rows, _) = client.fetch(1, 1, &[5], 1, &RowCodec::none()).unwrap();
    assert_eq!(rows, vec![vec![1.0f32]]);

    // crash: the first incarnation stops; a new one rebinds the same
    // path with the next round published
    drop(server);
    std::fs::remove_file(&path).unwrap();
    let listener = Listener::bind(&SockAddr::Unix(path.clone())).unwrap();
    let server = RowServer::spawn(listener, 1, 5, 2).unwrap();
    server.publish(2, &[vec![9.0f32], vec![8.0]], None);

    // the cached connection still points at the dead incarnation, which
    // can only serve its stale table: a named denial, never wrong data
    let err = format!("{:#}", client.fetch(2, 1, &[5], 1, &RowCodec::none()).unwrap_err());
    assert!(err.contains("peer worker 1"), "{err}");
    assert!(err.contains("round 2"), "{err}");

    // rejoin: reset + refetch re-dials the new incarnation
    client.reset_conns();
    let (rows, _) = client.fetch(2, 1, &[5], 1, &RowCodec::none()).unwrap();
    assert_eq!(rows, vec![vec![9.0f32]]);

    drop(client);
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// source lint: the virtual clock is the only clock
// ---------------------------------------------------------------------------

#[test]
fn no_wall_clock_reads_in_deterministic_modules() {
    // round timing must come from the virtual clock's counter streams;
    // a stray Instant/SystemTime in these modules would let real time
    // leak into results. Intentional uses (process-spawn deadlines,
    // reporting-only wall_secs) carry a `lint: wall-clock-exempt`
    // marker on the same or the preceding line. The scan is the real
    // `rpel::analysis` engine (single source of truth with `rpel lint`),
    // restricted to its `wall-clock` rule; rust/tests/lint.rs holds the
    // whole-tree assertion over every rule.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let rules: Vec<_> = rpel::analysis::default_rules()
        .into_iter()
        .filter(|r| r.id == "wall-clock")
        .collect();
    assert_eq!(rules.len(), 1, "the wall-clock rule must exist");
    let report = rpel::analysis::lint_tree(&root, &rules).unwrap();
    assert!(
        report.files_scanned >= 6,
        "lint scan is looking at the wrong tree: {} files under {}",
        report.files_scanned,
        root.display()
    );
    assert!(
        report.clean(),
        "wall-clock reads in deterministic modules — model time on the \
         virtual clock, or mark an intentional use with \
         `// lint: wall-clock-exempt`:\n{}",
        rpel::analysis::report::render_text(&report)
    );
}
