//! Large-n memory-diet smoke: the digest-based attack context must let a
//! 2048-node round run without materializing per-victim full scans
//! (ALIE is O(d) per victim; peak round state is the O(h·d) shard
//! buffers plus one O(d) digest — no O(h²) anything).
//!
//! Ignored by default (it is a CI smoke, not a unit test): run with
//! `cargo test --release --test large_n -- --ignored`.

use rpel::attacks::AttackKind;
use rpel::config::{EngineKind, ExperimentConfig, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;

#[test]
#[ignore = "large-n CI smoke (seconds in release, slow in debug)"]
fn n2048_two_rounds_native_alie() {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = "large_n_smoke".into();
    cfg.n = 2048;
    cfg.b = 204; // ~10% Byzantine
    cfg.topology = Topology::Epidemic { s: 8 };
    cfg.bhat = Some(3);
    cfg.attack = AttackKind::Alie;
    cfg.rounds = 2;
    cfg.batch = 8;
    cfg.samples_per_node = 16;
    cfg.test_samples = 64;
    cfg.eval_every = 1000; // final-round eval only
    cfg.engine = EngineKind::Native;
    cfg.threads = 0; // all cores
    cfg.shards = 4;
    let mut t = Trainer::from_config(&cfg).unwrap();
    assert_eq!(t.honest_count(), 2048 - 204);
    assert_eq!(t.shard_count(), 4);
    let hist = t.run().unwrap();
    assert_eq!(hist.train_loss.len(), 2);
    assert!(hist.train_loss.iter().all(|l| l.is_finite()));
    // every honest node saw at most b Byzantine rows
    assert!(hist.observed_byz_max.iter().all(|&m| m <= cfg.b));
    assert_eq!(hist.evals.len(), 1, "final-round eval only");
}
