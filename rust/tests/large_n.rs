//! Large-n memory-diet smokes.
//!
//! * `n2048_two_rounds_native_alie` — the digest-based attack context
//!   must let a 2048-node round run without materializing per-victim
//!   full scans (ALIE is O(d) per victim; peak round state is the
//!   O(h·d) shard buffers plus one O(d) digest — no O(h²) anything).
//! * `n_one_million_virtual_round_stays_lean` — the virtual-node
//!   backend must carry a **million**-node world through real rounds
//!   while keeping committed state as `(seed, delta log)`: the
//!   resident-bytes ledger must stay far below the n·d·4 a dense
//!   params table alone would cost.
//!
//! Ignored by default (they are CI smokes, not unit tests): run with
//! `cargo test --release --test large_n -- --ignored`.

use rpel::attacks::AttackKind;
use rpel::config::{EngineKind, ExperimentConfig, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;

#[test]
#[ignore = "large-n CI smoke (seconds in release, slow in debug)"]
fn n2048_two_rounds_native_alie() {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = "large_n_smoke".into();
    cfg.n = 2048;
    cfg.b = 204; // ~10% Byzantine
    cfg.topology = Topology::Epidemic { s: 8 };
    cfg.bhat = Some(3);
    cfg.attack = AttackKind::Alie;
    cfg.rounds = 2;
    cfg.batch = 8;
    cfg.samples_per_node = 16;
    cfg.test_samples = 64;
    cfg.eval_every = 1000; // final-round eval only
    cfg.engine = EngineKind::Native;
    cfg.threads = 0; // all cores
    cfg.shards = 4;
    let mut t = Trainer::from_config(&cfg).unwrap();
    assert_eq!(t.honest_count(), 2048 - 204);
    assert_eq!(t.shard_count(), 4);
    let hist = t.run().unwrap();
    assert_eq!(hist.train_loss.len(), 2);
    assert!(hist.train_loss.iter().all(|l| l.is_finite()));
    // every honest node saw at most b Byzantine rows
    assert!(hist.observed_byz_max.iter().all(|&m| m <= cfg.b));
    assert_eq!(hist.evals.len(), 1, "final-round eval only");
}

#[test]
#[ignore = "million-node virtual-round smoke (minutes in release, far slower in debug)"]
fn n_one_million_virtual_round_stays_lean() {
    const N: usize = 1_000_000;
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = "large_n_virtual_million".into();
    cfg.n = N;
    cfg.b = 0; // digest path skipped; this smoke referees memory, not robustness
    cfg.attack = AttackKind::None;
    cfg.topology = Topology::Epidemic { s: 8 };
    cfg.rounds = 2;
    cfg.batch = 8;
    cfg.samples_per_node = 16;
    cfg.test_samples = 32;
    cfg.eval_every = 100_000; // never: full-world eval would defeat the diet
    cfg.engine = EngineKind::Native;
    cfg.threads = 0; // all cores
    cfg.participation = 0.002; // ~2000 active nodes per round
    cfg.virtual_nodes = true;

    let mut t = Trainer::from_config(&cfg).unwrap();
    assert_eq!(t.honest_count(), N);
    let d = t.committed_params(0).len() as u64;

    // drive rounds directly (no run(): its final eval walks all n models)
    for round in 0..cfg.rounds {
        let loss = t.round(round).unwrap();
        assert!(loss.is_finite(), "round {round}: loss {loss}");

        let (active, materialized, resident) = t.sparse_round_stats(round);
        // binomial(n, 0.002): mean 2000, sd ~45 — these bounds are >20 sd out
        assert!(
            (1000..=4000).contains(&active),
            "round {round}: active={active} is not ~p·n"
        );
        assert!(materialized >= active, "round {round}: pulled rows count too");
        assert!(
            (materialized as usize) < N / 50,
            "round {round}: materialized={materialized} — lazy state is leaking"
        );
        // the memory-diet referee: everything resident (seed substrate,
        // delta logs, arenas, momentum, shards of touched nodes) must be
        // a small fraction of what a dense params table ALONE costs —
        // and dense would pay another n·d·4 for momentum on top
        let dense_params_bytes = N as u64 * d * 4;
        assert!(
            resident * 4 < dense_params_bytes,
            "round {round}: resident {resident} B is not \u{226a} dense n\u{b7}d\u{b7}4 = {dense_params_bytes} B"
        );
    }
}
