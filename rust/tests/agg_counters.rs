//! Row-pair evaluation ledger for the aggregation fast path.
//!
//! Lives in its own test binary on purpose: `aggregation::perf` counters
//! are process-wide, and the other suites (which also run aggregation)
//! would pollute the counts if these assertions shared their process.
//! The single #[test] below keeps the binary race-free.

use rpel::aggregation::perf;
use rpel::attacks::AttackKind;
use rpel::config::{EngineKind, ExperimentConfig, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;

fn cfg(n: usize, s: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = format!("agg_counters_n{n}");
    cfg.n = n;
    cfg.b = n / 10;
    cfg.topology = Topology::Epidemic { s };
    cfg.bhat = Some(2);
    cfg.attack = AttackKind::Alie;
    cfg.batch = 8;
    cfg.samples_per_node = 24;
    cfg.test_samples = 32;
    cfg.engine = EngineKind::Native;
    cfg.threads = 1; // deterministic single-thread ledger
    cfg
}

#[test]
fn cached_round_computes_strictly_fewer_pair_distances() {
    let (n, s) = (32usize, 8usize);
    let config = cfg(n, s);
    let victims = n - config.b;

    // cache ON: one round's ledger
    let mut on = Trainer::from_config(&config).unwrap();
    perf::reset_dist_pair_evals();
    on.round(0).unwrap();
    let cached = perf::dist_pair_evals();

    // cache OFF: same round's ledger
    let mut off = Trainer::from_config(&config).unwrap();
    off.set_dist_cache(false);
    perf::reset_dist_pair_evals();
    off.round(0).unwrap();
    let uncached = perf::dist_pair_evals();

    let naive_bound = (victims * (s + 1) * (s + 1)) as u64;
    assert!(cached > 0, "ledger recorded nothing — hook disconnected?");
    assert!(
        cached < uncached,
        "cache must strictly reduce evaluations: cached {cached}, uncached {uncached}"
    );
    assert!(
        cached < naive_bound,
        "cached round computed {cached} pair distances, naive bound is {naive_bound}"
    );
    // sanity on the uncached ledger: exactly one half-matrix per victim
    // (m = own row + s pulled rows, every pair evaluated once)
    let m = s + 1;
    assert_eq!(
        uncached,
        (victims * (m * (m - 1)) / 2) as u64,
        "uncached ledger should be victims × C(m, 2)"
    );
}
