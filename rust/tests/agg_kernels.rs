//! Oracle-equivalence suite for the aggregation fast path:
//!
//! * the Gram-blocked pairwise kernel and the blocked `dist_sq` stay
//!   within 1e-10 relative of the naive serial oracle (the FP policy is
//!   grid invariance, not seed identity — this pins the drift bound);
//! * cached and uncached NNM∘CWTM are **byte-identical**, at the rule
//!   level (forall) and end-to-end across the (shards × procs × threads)
//!   grid with the cache toggled;
//! * the selection-based per-coordinate trimmed sum / median is
//!   **bit-identical** to the sort-based path on random and adversarial
//!   (tied, denormal, mixed-magnitude, signed-zero, non-finite) inputs;
//! * NaN/±Inf adversarial rows cannot panic any distance-based rule and
//!   the output stays in the honest hull.

use rpel::aggregation::cwtm::{
    median_select_path, median_sort_path, trimmed_sum_select_path, trimmed_sum_sort_path,
};
use rpel::aggregation::{pairwise_sqdist, Aggregator, DistCache, RowCtx, RuleKind};
use rpel::attacks::AttackKind;
use rpel::config::ExperimentConfig;
use rpel::coordinator::Trainer;
use rpel::testkit::{forall, Gen};
use rpel::util::rng::Rng;
use rpel::util::vecmath;

fn naive_dist_sq(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x as f64) - (*y as f64);
        acc += d * d;
    }
    acc
}

fn naive_norm_sq(a: &[f32]) -> f64 {
    a.iter().map(|x| (*x as f64) * (*x as f64)).sum()
}

/// Random row set: (m rows, each of length d) with mixed magnitudes.
fn gen_rows(m_max: usize, d_max: usize) -> Gen<Vec<Vec<f32>>> {
    Gen::plain(move |rng: &mut Rng| {
        let m = 2 + rng.index(m_max - 1);
        let d = 1 + rng.index(d_max);
        let scale = [1.0f32, 1e-3, 1e3, 1e6][rng.index(4)];
        (0..m)
            .map(|_| (0..d).map(|_| rng.gaussian32(0.0, scale)).collect())
            .collect()
    })
}

#[test]
fn blocked_dist_sq_within_1e10_of_naive_oracle() {
    forall(300, 11, gen_rows(3, 600), |rows| {
        let a = &rows[0];
        let b = &rows[1];
        if a.len() != b.len() {
            return true; // gen gives equal lengths; belt and braces
        }
        let naive = naive_dist_sq(a, b);
        let blocked = vecmath::dist_sq(a, b);
        (blocked - naive).abs() <= 1e-10 * naive.max(1e-300)
    });
    // the d = 10⁵ regime the issue names, deterministic
    let mut rng = Rng::new(5);
    let a: Vec<f32> = (0..100_000).map(|_| rng.gaussian32(0.0, 1.0)).collect();
    let b: Vec<f32> = (0..100_000).map(|_| rng.gaussian32(0.5, 2.0)).collect();
    let naive = naive_dist_sq(&a, &b);
    let blocked = vecmath::dist_sq(&a, &b);
    assert!(
        (blocked - naive).abs() <= 1e-10 * naive,
        "d=1e5: naive {naive}, blocked {blocked}"
    );
}

#[test]
fn gram_pairwise_within_1e10_of_naive_oracle() {
    // the Gram identity cancels, so the drift bound is relative to the
    // norm scale that sets its ulps (equal to the distance scale for
    // the independent rows generated here)
    forall(200, 12, gen_rows(8, 400), |rows| {
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let m = refs.len();
        let gram = pairwise_sqdist(&refs);
        for i in 0..m {
            for j in (i + 1)..m {
                let naive = naive_dist_sq(refs[i], refs[j]);
                let scale = (naive_norm_sq(refs[i]) + naive_norm_sq(refs[j])).max(naive);
                if (gram[i * m + j] - naive).abs() > 1e-10 * scale.max(1e-300) {
                    return false;
                }
            }
        }
        true
    });
    // d = 10⁵ point
    let mut rng = Rng::new(6);
    let rows: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..100_000).map(|_| rng.gaussian32(0.0, 3.0)).collect())
        .collect();
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let gram = pairwise_sqdist(&refs);
    for i in 0..4 {
        for j in (i + 1)..4 {
            let naive = naive_dist_sq(refs[i], refs[j]);
            assert!(
                (gram[i * 4 + j] - naive).abs() <= 1e-10 * naive,
                "({i},{j}): naive {naive}, gram {}",
                gram[i * 4 + j]
            );
        }
    }
}

#[test]
fn gram_guard_keeps_near_identical_rows_distance_accurate() {
    // the cancellation regime: rows with large norms and tiny
    // separation (converged half-steps / mimic adversaries). The raw
    // Gram identity's error here is ~d·ε·‖a‖² — orders of magnitude
    // larger than the true distance — so the kernel must fall back to
    // the direct subtract-square path and stay distance-relative.
    let mut rng = Rng::new(9);
    let d = 50_000usize;
    let base: Vec<f32> = (0..d).map(|_| rng.gaussian32(0.0, 1e3)).collect();
    // three rows ε-close to `base` at distinct distances, plus base
    let mut rows = vec![base.clone()];
    for k in 1..=3u32 {
        let eps = 1e-4f32 * k as f32;
        rows.push(base.iter().map(|&x| x + eps).collect());
    }
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let m = refs.len();
    let gram = pairwise_sqdist(&refs);
    for i in 0..m {
        for j in (i + 1)..m {
            let naive = naive_dist_sq(refs[i], refs[j]);
            let got = gram[i * m + j];
            assert!(
                (got - naive).abs() <= 1e-10 * naive,
                "({i},{j}): naive {naive}, got {got} — cancellation guard failed"
            );
        }
    }
    // and the ranking NNM derives from it is the true one: base's
    // nearest neighbors in order are rows 1, 2, 3
    assert!(gram[1] < gram[2] && gram[2] < gram[3], "{gram:?}");
}

#[test]
fn cached_nnm_cwtm_is_byte_identical_forall() {
    // per-rule property: with every row identified, with a per-victim
    // (unidentified) minority, cold and warm — always the plain bits
    forall(120, 13, gen_rows(9, 120), |rows| {
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let m = refs.len();
        let d = refs[0].len();
        let b = (m - 1) / 2;
        let rule = RuleKind::NnmCwtm.build(b.min(2));
        let mut plain = vec![0.0f32; d];
        rule.aggregate(&refs, &mut plain);
        let plain_bits: Vec<u32> = plain.iter().map(|x| x.to_bits()).collect();
        // ids: last row unidentified when m > 2 (a "crafted" row)
        let ids: Vec<Option<u32>> = (0..m)
            .map(|i| if m > 2 && i == m - 1 { None } else { Some(i as u32) })
            .collect();
        let cache = DistCache::new();
        let ctx = RowCtx { ids: &ids, cache: Some(&cache) };
        for _pass in 0..2 {
            let mut out = vec![0.0f32; d];
            rule.aggregate_with_ctx(&refs, &ctx, &mut out);
            let bits: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
            if bits != plain_bits {
                return false;
            }
        }
        true
    });
}

#[test]
fn selection_stats_bit_identical_to_sort_path() {
    // adversarial value classes the selection partition must order
    // exactly like the reference insertion sort: ties, denormals, mixed
    // magnitudes, signed zeros, non-finite payloads
    let gen = Gen::plain(|rng: &mut Rng| {
        let m = 3 + rng.index(62);
        let mode = rng.index(5);
        let vals: Vec<f32> = (0..m)
            .map(|_| match mode {
                0 => rng.gaussian32(0.0, 1e3),
                1 => [-1.0f32, 0.0, 1.0, 2.0][rng.index(4)], // heavy ties
                2 => [1e-42f32, -1e-42, 1e-40, -1e-40][rng.index(4)], // denormals
                3 => rng.gaussian32(0.0, 1.0) * [1e-30f32, 1.0, 1e30][rng.index(3)],
                _ => [0.0f32, -0.0, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0]
                    [rng.index(6)],
            })
            .collect();
        let b = rng.index((m - 1) / 2 + 1); // 0 ≤ b ≤ (m-1)/2 ⇒ m > 2b
        (vals, b)
    });
    forall(500, 14, gen, |(vals, b)| {
        let sum_sort = trimmed_sum_sort_path(vals, *b);
        let sum_select = trimmed_sum_select_path(vals, *b);
        let med_sort = median_sort_path(vals);
        let med_select = median_select_path(vals);
        sum_sort.to_bits() == sum_select.to_bits()
            && med_sort.to_bits() == med_select.to_bits()
    });
}

#[test]
fn non_finite_rows_stay_in_hull_for_every_nnm_composite() {
    // NaN and ±Inf are legal adversarial payloads; every distance-based
    // composite must absorb them without a panic and land in the hull
    let data = vec![
        vec![0.0f32, 1.0],
        vec![0.1, 1.1],
        vec![0.2, 0.9],
        vec![0.15, 1.05],
        vec![0.05, 0.95],
        vec![0.12, 1.02],
        vec![f32::NAN, f32::INFINITY],
        vec![f32::NEG_INFINITY, f32::NAN],
    ];
    let refs: Vec<&[f32]> = data.iter().map(|v| v.as_slice()).collect();
    for kind in [RuleKind::NnmCwtm, RuleKind::NnmCwMed, RuleKind::NnmKrum, RuleKind::Krum] {
        let rule = kind.build(2);
        let mut out = vec![0.0f32; 2];
        rule.aggregate(&refs, &mut out);
        assert!(
            out.iter().all(|v| v.is_finite()),
            "{}: non-finite output {out:?}",
            kind.name()
        );
        assert!(
            (0.0..=0.2).contains(&out[0]) && (0.9..=1.1).contains(&out[1]),
            "{}: out of honest hull {out:?}",
            kind.name()
        );
    }
}

// ---------------------------------------------------------------------------
// End-to-end: the cache toggle across the engine grid
// ---------------------------------------------------------------------------

fn grid_cfg() -> ExperimentConfig {
    use rpel::config::{EngineKind, Topology};
    use rpel::data::TaskKind;
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.n = 12;
    cfg.b = 2;
    cfg.topology = Topology::Epidemic { s: 6 };
    cfg.bhat = Some(2);
    cfg.attack = AttackKind::Alie;
    cfg.rounds = 6;
    cfg.batch = 8;
    cfg.samples_per_node = 48;
    cfg.test_samples = 96;
    cfg.eval_every = 3;
    cfg.engine = EngineKind::Native;
    cfg
}

/// Run and collect the bit-comparable outputs.
fn run_collect(cfg: &ExperimentConfig, cache_on: bool) -> (Vec<u64>, Vec<Vec<u32>>) {
    let mut t = Trainer::from_config(cfg).unwrap();
    t.set_dist_cache(cache_on);
    let hist = t.run().unwrap();
    let losses: Vec<u64> = hist.train_loss.iter().map(|x| x.to_bits()).collect();
    let params: Vec<Vec<u32>> = (0..t.honest_count())
        .map(|i| t.params_of(i).iter().map(|x| x.to_bits()).collect())
        .collect();
    (losses, params)
}

#[test]
fn cache_toggle_is_byte_invisible_across_the_grid() {
    // reference: cache OFF, serial, single shard
    let mut off_cfg = grid_cfg();
    off_cfg.shards = 1;
    off_cfg.threads = 1;
    let reference = run_collect(&off_cfg, false);
    // cache ON across the in-process (shards × threads) grid
    for shards in [1usize, 2, 3] {
        for threads in [1usize, 4] {
            let mut cfg = grid_cfg();
            cfg.shards = shards;
            cfg.threads = threads;
            let got = run_collect(&cfg, true);
            assert_eq!(
                reference, got,
                "cache-on shards={shards} threads={threads} diverged from cache-off serial"
            );
        }
    }
}

#[test]
fn worker_processes_cache_is_byte_invisible_too() {
    // the multi-process engine always caches in each worker; it must
    // reproduce the cache-off in-process run bit-for-bit. Pin the worker
    // binary first (test binaries live in deps/, where the default
    // sibling resolution may not find — or may find a stale — `rpel`).
    rpel::coordinator::proc::set_worker_bin(env!("CARGO_BIN_EXE_rpel"));
    let mut off_cfg = grid_cfg();
    off_cfg.threads = 1;
    let reference = run_collect(&off_cfg, false);
    let mut cfg = grid_cfg();
    cfg.procs = 2;
    cfg.threads = 1;
    let got = run_collect(&cfg, true);
    assert_eq!(reference, got, "procs=2 (worker caches) vs cache-off in-process");
}
