//! Wire-codec guarantees behind the multi-process shard engine:
//!
//! * **encode ∘ decode = id**, bit-wise, for random shard payloads
//!   (`testkit::forall` over random h/d row blocks and f64 digest
//!   partials) — the property cross-process bit-identity rests on —
//!   including the socket-transport frames (`PeerHello`, `PullRequest`,
//!   `PullReply`, `Peers`, `AggregateRouted`);
//! * **committed golden vectors**: the byte layout is pinned literally,
//!   so an accidental codec change breaks loudly instead of silently
//!   desyncing coordinator and workers;
//! * **compressed row blocks** (protocol v4): f16/q8 Snapshot and
//!   PullReply frames round-trip to the *decoded* bits (the bits every
//!   consumer aggregates), gathered sub-blocks serve cached segments
//!   verbatim, non-finite values saturate per the codec spec, and
//!   `compression = none` framing is byte-identical to the legacy
//!   encoders;
//! * truncated or corrupt buffers — oversized row blocks, zero-width
//!   rows, absurd route counts, wrong-version handshakes — decode to
//!   errors, never panics.

use rpel::attacks::HonestDigest;
use rpel::testkit::{forall, Gen};
use rpel::util::rng::Rng;
use rpel::wire::codec::{self, Compression, RowCodec};
use rpel::wire::proto::{self, FromWorker, PeerEntry, PeerMsg, ToWorker, WireDigest};

fn bits32(rows: &[Vec<f32>]) -> Vec<Vec<u32>> {
    rows.iter()
        .map(|r| r.iter().map(|x| x.to_bits()).collect())
        .collect()
}

fn bits64(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Random shard snapshot: h in [1,6] nodes, d in [1,9] coords, values
/// spanning signs, magnitudes, and exact zeros.
fn snapshot_gen() -> Gen<(Vec<f64>, Vec<Vec<f32>>)> {
    Gen::plain(|rng: &mut Rng| {
        let h = 1 + rng.index(6);
        let d = 1 + rng.index(9);
        let losses: Vec<f64> = (0..h).map(|_| (rng.f64() - 0.5) * 1e3).collect();
        let halves: Vec<Vec<f32>> = (0..h)
            .map(|_| {
                (0..d)
                    .map(|_| {
                        let x = (rng.f32() - 0.5) * 2.0;
                        if x.abs() < 0.01 {
                            0.0
                        } else {
                            x * 10f32.powi(rng.index(7) as i32 - 3)
                        }
                    })
                    .collect()
            })
            .collect();
        (losses, halves)
    })
}

#[test]
fn snapshot_encode_decode_is_identity() {
    forall(300, 0xA11CE, snapshot_gen(), |(losses, halves)| {
        let buf = proto::encode_snapshot(41, losses, halves);
        match proto::decode_from_worker(&buf) {
            Ok(FromWorker::Snapshot {
                round,
                losses: l2,
                halves: h2,
            }) => round == 41 && bits64(losses) == bits64(&l2) && bits32(halves) == bits32(&h2),
            _ => false,
        }
    });
}

#[test]
fn aggregate_encode_decode_is_identity_with_f64_partials() {
    forall(300, 0xD16E57, snapshot_gen(), |(partials, halves)| {
        // reuse the generated f64 vector as digest partials
        let digest = HonestDigest {
            count: partials.len(),
            mean: partials.clone(),
            std: partials.iter().map(|x| x.abs()).collect(),
            prev_mean: partials.iter().map(|x| -x).collect(),
        };
        let buf = proto::encode_aggregate(7, &digest, halves);
        match proto::decode_to_worker(&buf) {
            Ok(ToWorker::Aggregate {
                round,
                digest: d2,
                halves: h2,
            }) => {
                round == 7
                    && d2.count == digest.count as u64
                    && bits64(&digest.mean) == bits64(&d2.mean)
                    && bits64(&digest.std) == bits64(&d2.std)
                    && bits64(&digest.prev_mean) == bits64(&d2.prev_mean)
                    && bits32(halves) == bits32(&h2)
            }
            _ => false,
        }
    });
}

#[test]
fn round_done_encode_decode_is_identity() {
    forall(200, 0xB0B, snapshot_gen(), |(_, params)| {
        let n = params.len();
        let byz: Vec<u32> = (0..n as u32).collect();
        let recv: Vec<u32> = (0..n as u32).map(|x| x * 3 + 1).collect();
        let peer_bytes = n as u64 * 1017;
        let retries = n as u32 % 5;
        let buf = proto::encode_round_done(9, &byz, &recv, peer_bytes, retries, params);
        match proto::decode_from_worker(&buf) {
            Ok(FromWorker::RoundDone {
                round,
                byz_seen,
                received,
                peer_bytes: pb,
                retries: rt,
                params: p2,
            }) => {
                round == 9
                    && byz_seen == byz
                    && received == recv
                    && pb == peer_bytes
                    && rt == retries
                    && bits32(params) == bits32(&p2)
            }
            _ => false,
        }
    });
}

#[test]
fn state_encode_decode_is_identity() {
    // the recovery drain barrier: params + momentum + sparse carried rows
    forall(200, 0x57A7E, snapshot_gen(), |(_, rows)| {
        let momentum: Vec<Vec<f32>> = rows
            .iter()
            .map(|r| r.iter().map(|x| -x * 0.5).collect())
            .collect();
        let carried: Vec<Option<Vec<f32>>> = rows
            .iter()
            .enumerate()
            .map(|(i, r)| (i % 2 == 0).then(|| r.clone()))
            .collect();
        let buf = proto::encode_state(13, rows, &momentum, &carried);
        match proto::decode_from_worker(&buf) {
            Ok(FromWorker::State {
                round,
                params: p2,
                momentum: m2,
                carried: c2,
            }) => {
                round == 13
                    && bits32(rows) == bits32(&p2)
                    && bits32(&momentum) == bits32(&m2)
                    && carried == c2
            }
            _ => false,
        }
    });
}

#[test]
fn init_resume_encode_decode_is_identity() {
    // a worker Init carrying checkpoint boundary state must round-trip
    // bit-for-bit — it is the resume path's only channel into a worker
    forall(200, 0x2E5E, snapshot_gen(), |(_, rows)| {
        let resume = proto::WireResume {
            round: 6,
            wire_ref: rows[0].clone(),
            params: rows.clone(),
            momentum: rows.iter().map(|r| r.iter().map(|x| x * 2.0).collect()).collect(),
            carried: rows.iter().map(|r| Some(r.clone())).collect(),
        };
        let buf = proto::encode_init("task = \"tiny\"", 1, 2, &resume);
        match proto::decode_to_worker(&buf) {
            Ok(ToWorker::Init {
                worker: 1,
                procs: 2,
                resume: r2,
                ..
            }) => {
                r2.round == resume.round
                    && bits32(&[r2.wire_ref.clone()]) == bits32(&[resume.wire_ref.clone()])
                    && bits32(&resume.params) == bits32(&r2.params)
                    && bits32(&resume.momentum) == bits32(&r2.momentum)
                    && resume.carried == r2.carried
            }
            _ => false,
        }
    });
}

#[test]
fn pull_reply_encode_decode_is_identity() {
    // the peer-served rows ride the same row-block primitive as the
    // broadcast table: bit-exactness must hold here too
    forall(300, 0x9EE7, snapshot_gen(), |(_, rows)| {
        let idx: Vec<u32> = (0..rows.len() as u32).map(|x| x * 5 + 2).collect();
        let req = proto::encode_pull_request(17, &idx);
        let reply = proto::encode_pull_reply(17, rows);
        let req_ok = matches!(
            proto::decode_peer(&req),
            Ok(PeerMsg::PullRequest { round: 17, rows: r }) if r == idx
        );
        let reply_ok = match proto::decode_peer(&reply) {
            Ok(PeerMsg::PullReply { round, rows: r2 }) => {
                round == 17 && bits32(rows) == bits32(&r2)
            }
            _ => false,
        };
        req_ok && reply_ok
    });
}

#[test]
fn aggregate_routed_encode_decode_is_identity() {
    forall(300, 0x10C4, snapshot_gen(), |(partials, halves)| {
        let digest = HonestDigest {
            count: partials.len(),
            mean: partials.clone(),
            std: vec![],
            prev_mean: partials.iter().map(|x| x * 0.5).collect(),
        };
        // derive a ragged routing table from the generated rows
        let routes: Vec<Vec<u32>> = halves
            .iter()
            .enumerate()
            .map(|(i, row)| (0..i % 4).map(|k| (row.len() + k) as u32).collect())
            .collect();
        let buf = proto::encode_aggregate_routed(23, &digest, &routes);
        match proto::decode_to_worker(&buf) {
            Ok(ToWorker::AggregateRouted {
                round,
                digest: d2,
                routes: r2,
            }) => {
                round == 23
                    && d2.count == digest.count as u64
                    && bits64(&digest.mean) == bits64(&d2.mean)
                    && bits64(&digest.prev_mean) == bits64(&d2.prev_mean)
                    && r2 == routes
            }
            _ => false,
        }
    });
}

// ---------------------------------------------------------------------------
// Golden vectors: the committed byte layout. If any of these fail, the
// wire format changed — bump PROTOCOL_VERSION and regenerate.
// ---------------------------------------------------------------------------

#[test]
fn golden_half_step() {
    let expect: [u8; 9] = [0x02, 3, 0, 0, 0, 0, 0, 0, 0];
    assert_eq!(proto::encode_half_step(3), expect);
    assert_eq!(
        proto::decode_to_worker(&expect).unwrap(),
        ToWorker::HalfStep { round: 3 }
    );
}

#[test]
fn golden_async_round() {
    // round = 6, stale = [0, 2, 1]
    let expect: [u8; 25] = [
        0x07, // tag
        6, 0, 0, 0, 0, 0, 0, 0, // round = 6
        0x03, 0x00, 0x00, 0x00, // 3 staleness entries
        0x00, 0x00, 0x00, 0x00, // stale[0] = 0 (fresh)
        0x02, 0x00, 0x00, 0x00, // stale[1] = 2
        0x01, 0x00, 0x00, 0x00, // stale[2] = 1
    ];
    assert_eq!(proto::encode_async_round(6, &[0, 2, 1]), expect);
    assert_eq!(
        proto::decode_to_worker(&expect).unwrap(),
        ToWorker::AsyncRound {
            round: 6,
            stale: vec![0, 2, 1]
        }
    );
}

#[test]
fn golden_snapshot() {
    // round = 3, losses = [1.0f64], halves = [[1.0f32, -2.0f32]]
    let expect: [u8; 37] = [
        0x82, // tag
        3, 0, 0, 0, 0, 0, 0, 0, // round echo = 3
        0x01, 0x00, 0x00, 0x00, // 1 loss
        0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF0, 0x3F, // f64 1.0
        0x01, 0x00, 0x00, 0x00, // 1 row
        0x02, 0x00, 0x00, 0x00, // d = 2
        0x00, 0x00, 0x80, 0x3F, // f32 1.0
        0x00, 0x00, 0x00, 0xC0, // f32 -2.0
    ];
    let buf = proto::encode_snapshot(3, &[1.0f64], &[vec![1.0f32, -2.0f32]]);
    assert_eq!(buf, expect);
    match proto::decode_from_worker(&expect).unwrap() {
        FromWorker::Snapshot {
            round,
            losses,
            halves,
        } => {
            assert_eq!(round, 3);
            assert_eq!(losses, vec![1.0f64]);
            assert_eq!(halves, vec![vec![1.0f32, -2.0f32]]);
        }
        other => panic!("wrong message: {other:?}"),
    }
}

#[test]
fn golden_aggregate() {
    // round 5; digest: count=2, mean=[0.5], std=[], prev_mean=[-1.0];
    // halves = [[0.25f32]]
    let digest = HonestDigest {
        count: 2,
        mean: vec![0.5],
        std: vec![],
        prev_mean: vec![-1.0],
    };
    let expect: [u8; 57] = [
        0x03, // tag
        5, 0, 0, 0, 0, 0, 0, 0, // round = 5
        2, 0, 0, 0, 0, 0, 0, 0, // count = 2
        0x01, 0x00, 0x00, 0x00, // 1 mean coord
        0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xE0, 0x3F, // f64 0.5
        0x00, 0x00, 0x00, 0x00, // 0 std coords
        0x01, 0x00, 0x00, 0x00, // 1 prev-mean coord
        0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF0, 0xBF, // f64 -1.0
        0x01, 0x00, 0x00, 0x00, // 1 row
        0x01, 0x00, 0x00, 0x00, // d = 1
        0x00, 0x00, 0x80, 0x3E, // f32 0.25
    ];
    let buf = proto::encode_aggregate(5, &digest, &[vec![0.25f32]]);
    assert_eq!(buf, expect);
    match proto::decode_to_worker(&expect).unwrap() {
        ToWorker::Aggregate {
            round,
            digest: d2,
            halves,
        } => {
            assert_eq!(round, 5);
            assert_eq!(
                d2,
                WireDigest {
                    count: 2,
                    mean: vec![0.5],
                    std: vec![],
                    prev_mean: vec![-1.0],
                }
            );
            assert_eq!(halves, vec![vec![0.25f32]]);
        }
        other => panic!("wrong message: {other:?}"),
    }
}

#[test]
fn golden_round_done() {
    let expect: [u8; 49] = [
        0x83, // tag
        5, 0, 0, 0, 0, 0, 0, 0, // round echo = 5
        0x01, 0x00, 0x00, 0x00, // 1 byz count
        0x01, 0x00, 0x00, 0x00, // byz_seen[0] = 1
        0x01, 0x00, 0x00, 0x00, // 1 recv count
        0x06, 0x00, 0x00, 0x00, // received[0] = 6
        7, 0, 0, 0, 0, 0, 0, 0, // peer_bytes = 7
        0x02, 0x00, 0x00, 0x00, // retries = 2
        0x01, 0x00, 0x00, 0x00, // 1 row
        0x01, 0x00, 0x00, 0x00, // d = 1
        0x00, 0x00, 0x20, 0x40, // f32 2.5
    ];
    let buf = proto::encode_round_done(5, &[1], &[6], 7, 2, &[vec![2.5f32]]);
    assert_eq!(buf, expect);
}

#[test]
fn golden_get_state() {
    let expect: [u8; 9] = [0x08, 4, 0, 0, 0, 0, 0, 0, 0];
    assert_eq!(proto::encode_get_state(4), expect);
    assert_eq!(
        proto::decode_to_worker(&expect).unwrap(),
        ToWorker::GetState { round: 4 }
    );
}

#[test]
fn golden_state() {
    // round 4; params = [[0.5]], momentum = [[-1.0]], carried = [None]
    let expect: [u8; 46] = [
        0x84, // tag
        4, 0, 0, 0, 0, 0, 0, 0, // round = 4
        0x01, 0x00, 0x00, 0x00, // 1 params row
        0x01, 0x00, 0x00, 0x00, // d = 1
        0x00, 0x00, 0x00, 0x3F, // f32 0.5
        0x01, 0x00, 0x00, 0x00, // 1 momentum row
        0x01, 0x00, 0x00, 0x00, // d = 1
        0x00, 0x00, 0x80, 0xBF, // f32 -1.0
        0x01, 0x00, 0x00, 0x00, // 1 carried slot
        0x00, // slot 0 absent
        0x00, 0x00, 0x00, 0x00, // 0 present rows
        0x00, 0x00, 0x00, 0x00, // d = 0 (no rows)
    ];
    let buf = proto::encode_state(4, &[vec![0.5f32]], &[vec![-1.0f32]], &[None]);
    assert_eq!(buf, expect);
}

#[test]
fn golden_shutdown_and_init_ok() {
    assert_eq!(proto::encode_shutdown(), vec![0x04]);
    // InitOk: tag, version 5, start=3, len=4, d=10
    let expect: [u8; 29] = [
        0x81, // tag
        0x05, 0x00, 0x00, 0x00, // protocol version 5
        3, 0, 0, 0, 0, 0, 0, 0, // start
        4, 0, 0, 0, 0, 0, 0, 0, // len
        10, 0, 0, 0, 0, 0, 0, 0, // d
    ];
    assert_eq!(proto::encode_init_ok(3, 4, 10), expect);
}

#[test]
fn golden_peer_hello() {
    let expect: [u8; 18] = [
        0x40, // tag
        0x05, 0x00, 0x00, 0x00, // protocol version 5
        0x01, 0x00, 0x00, 0x00, // worker = 1
        0x02, 0x00, 0x00, 0x00, // incarnation = 2 (second respawn)
        0x01, 0x00, 0x00, 0x00, // 1-byte address
        b'u',
    ];
    assert_eq!(proto::encode_peer_hello(1, 2, "u"), expect);
    assert_eq!(
        proto::decode_peer(&expect).unwrap(),
        PeerMsg::Hello {
            worker: 1,
            incarnation: 2,
            listen: "u".into()
        }
    );
}

#[test]
fn golden_pull_request_and_reply() {
    let expect_req: [u8; 21] = [
        0x41, // tag
        3, 0, 0, 0, 0, 0, 0, 0, // round = 3
        0x02, 0x00, 0x00, 0x00, // 2 rows requested
        0x07, 0x00, 0x00, 0x00, // row 7
        0x09, 0x00, 0x00, 0x00, // row 9
    ];
    assert_eq!(proto::encode_pull_request(3, &[7, 9]), expect_req);

    let expect_reply: [u8; 21] = [
        0x42, // tag
        3, 0, 0, 0, 0, 0, 0, 0, // round echo = 3
        0x01, 0x00, 0x00, 0x00, // 1 row
        0x01, 0x00, 0x00, 0x00, // d = 1
        0x00, 0x00, 0x00, 0x3F, // f32 0.5
    ];
    assert_eq!(proto::encode_pull_reply(3, &[vec![0.5f32]]), expect_reply);
    match proto::decode_peer(&expect_reply).unwrap() {
        PeerMsg::PullReply { round, rows } => {
            assert_eq!(round, 3);
            assert_eq!(rows, vec![vec![0.5f32]]);
        }
        other => panic!("wrong message: {other:?}"),
    }
}

#[test]
fn golden_peers() {
    let expect: [u8; 28] = [
        0x05, // tag
        0x01, 0x00, 0x00, 0x00, // 1 entry
        2, 0, 0, 0, 0, 0, 0, 0, // start = 2
        3, 0, 0, 0, 0, 0, 0, 0, // len = 3
        0x03, 0x00, 0x00, 0x00, // 3-byte address
        b'u', b':', b'x',
    ];
    let buf = proto::encode_peers(&[PeerEntry {
        start: 2,
        len: 3,
        addr: "u:x".into(),
    }]);
    assert_eq!(buf, expect);
}

#[test]
fn golden_aggregate_routed() {
    // round 4; digest: count=1, mean=[0.5], std=[], prev_mean=[-1.0];
    // one victim receiving from nodes [2, 0]
    let digest = HonestDigest {
        count: 1,
        mean: vec![0.5],
        std: vec![],
        prev_mean: vec![-1.0],
    };
    let expect: [u8; 61] = [
        0x06, // tag
        4, 0, 0, 0, 0, 0, 0, 0, // round = 4
        1, 0, 0, 0, 0, 0, 0, 0, // count = 1
        0x01, 0x00, 0x00, 0x00, // 1 mean coord
        0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xE0, 0x3F, // f64 0.5
        0x00, 0x00, 0x00, 0x00, // 0 std coords
        0x01, 0x00, 0x00, 0x00, // 1 prev-mean coord
        0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF0, 0xBF, // f64 -1.0
        0x01, 0x00, 0x00, 0x00, // 1 victim
        0x02, 0x00, 0x00, 0x00, // 2 sources
        0x02, 0x00, 0x00, 0x00, // node 2
        0x00, 0x00, 0x00, 0x00, // node 0
    ];
    let buf = proto::encode_aggregate_routed(4, &digest, &[vec![2, 0]]);
    assert_eq!(buf, expect);
    match proto::decode_to_worker(&expect).unwrap() {
        ToWorker::AggregateRouted {
            round,
            digest: d2,
            routes,
        } => {
            assert_eq!(round, 4);
            assert_eq!(d2.count, 1);
            assert_eq!(d2.mean, vec![0.5]);
            assert_eq!(routes, vec![vec![2, 0]]);
        }
        other => panic!("wrong message: {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Compressed row blocks (protocol v4): golden vectors, decoded-bits
// round-trips, saturation, and none ≡ legacy framing.
// ---------------------------------------------------------------------------

#[test]
fn golden_snapshot_f16_block() {
    // ref = [0.5, 0.5], row = [1.5, -1.5] → deltas [1.0, -2.0] →
    // binary16 bits 0x3C00, 0xC000. Decoded rows are ref + f16(delta).
    let reference = [0.5f32, 0.5];
    let rc = RowCodec::new(Compression::F16, &reference);
    let mut rows = vec![vec![1.5f32, -1.5]];
    let block = codec::transform_rows(&rc, &mut rows).unwrap();
    let expect: [u8; 33] = [
        0x82, // tag
        3, 0, 0, 0, 0, 0, 0, 0, // round echo = 3
        0x01, 0x00, 0x00, 0x00, // 1 loss
        0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF0, 0x3F, // f64 1.0
        0x01, 0x00, 0x00, 0x00, // 1 row
        0x02, 0x00, 0x00, 0x00, // d = 2
        0x00, 0x3C, // f16 delta 1.0
        0x00, 0xC0, // f16 delta -2.0
    ];
    let buf = proto::encode_snapshot_block(3, &[1.0f64], &block);
    assert_eq!(buf, expect);
    // deltas are exactly representable, so the decoded bits recover the
    // original row through the reference
    assert_eq!(rows, vec![vec![1.5f32, -1.5]]);
    match proto::decode_from_worker_c(&expect, &rc).unwrap() {
        FromWorker::Snapshot { round, halves, .. } => {
            assert_eq!(round, 3);
            assert_eq!(bits32(&halves), bits32(&rows));
        }
        other => panic!("wrong message: {other:?}"),
    }
}

#[test]
fn golden_pull_reply_q8_block() {
    // zero reference, row [0, 63.5, -127, 127] → m = 127, scale = 1.0,
    // quanta [0, 64 (half-away), -127, 127].
    let reference = [0.0f32; 4];
    let rc = RowCodec::new(Compression::Q8, &reference);
    let mut rows = vec![vec![0.0f32, 63.5, -127.0, 127.0]];
    let block = codec::transform_rows(&rc, &mut rows).unwrap();
    let expect: [u8; 25] = [
        0x42, // tag
        7, 0, 0, 0, 0, 0, 0, 0, // round echo = 7
        0x01, 0x00, 0x00, 0x00, // 1 row
        0x04, 0x00, 0x00, 0x00, // d = 4
        0x00, 0x00, 0x80, 0x3F, // f32 scale 1.0
        0x00, // k = 0
        0x40, // k = 64 (63.5 rounds half away from zero)
        0x81, // k = -127
        0x7F, // k = +127
    ];
    let buf = proto::encode_pull_reply_block(7, &block);
    assert_eq!(buf, expect);
    assert_eq!(rows, vec![vec![0.0f32, 64.0, -127.0, 127.0]]);
    match proto::decode_peer_c(&expect, &rc).unwrap() {
        PeerMsg::PullReply { round, rows: r2 } => {
            assert_eq!(round, 7);
            assert_eq!(bits32(&rows), bits32(&r2));
        }
        other => panic!("wrong message: {other:?}"),
    }
}

#[test]
fn compressed_snapshot_roundtrip_hits_the_decoded_bits() {
    // the wire contract under compression: the frame decodes to exactly
    // the bits `transform_rows` left behind at the publish point — the
    // bits every consumer aggregates
    for (comp, seed) in [(Compression::F16, 0xF16), (Compression::Q8, 0x0508)] {
        forall(200, seed, snapshot_gen(), |(losses, halves)| {
            let d = halves[0].len();
            let reference: Vec<f32> = (0..d).map(|i| i as f32 * 0.25 - 0.5).collect();
            let rc = RowCodec::new(comp, &reference);
            let mut decoded = halves.clone();
            let block = codec::transform_rows(&rc, &mut decoded).unwrap();
            let frame = proto::encode_snapshot_block(11, losses, &block);
            match proto::decode_from_worker_c(&frame, &rc) {
                Ok(FromWorker::Snapshot {
                    round,
                    losses: l2,
                    halves: h2,
                }) => {
                    round == 11
                        && bits64(losses) == bits64(&l2)
                        && bits32(&decoded) == bits32(&h2)
                }
                _ => false,
            }
        });
    }
}

#[test]
fn compressed_pull_reply_serves_gathered_segments_verbatim() {
    for (comp, seed) in [(Compression::F16, 0x6A01), (Compression::Q8, 0x6A02)] {
        forall(200, seed, snapshot_gen(), |(_, halves)| {
            let d = halves[0].len();
            let reference: Vec<f32> = (0..d).map(|i| 0.125 * i as f32).collect();
            let rc = RowCodec::new(comp, &reference);
            let mut decoded = halves.clone();
            let block = codec::transform_rows(&rc, &mut decoded).unwrap();
            // pull every other row, reversed — exercises non-trivial order
            let idx: Vec<usize> = (0..decoded.len()).step_by(2).rev().collect();
            let sub = block.gather(&idx).unwrap();
            let frame = proto::encode_pull_reply_block(17, &sub);
            let want: Vec<Vec<u32>> = idx
                .iter()
                .map(|&i| decoded[i].iter().map(|x| x.to_bits()).collect())
                .collect();
            match proto::decode_peer_c(&frame, &rc) {
                Ok(PeerMsg::PullReply { round, rows }) => {
                    round == 17 && bits32(&rows) == want
                }
                _ => false,
            }
        });
    }
}

#[test]
fn non_finite_values_saturate_never_panic() {
    // f16: NaN canonicalizes, ±Inf and overflow saturate to ±Inf
    let reference = [0.0f32; 4];
    let rc = RowCodec::new(Compression::F16, &reference);
    let mut rows = vec![vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1e9f32]];
    let block = codec::transform_rows(&rc, &mut rows).unwrap();
    let r = &rows[0];
    assert!(r[0].is_nan());
    assert_eq!(r[1], f32::INFINITY);
    assert_eq!(r[2], f32::NEG_INFINITY);
    assert_eq!(r[3], f32::INFINITY); // 1e9 overflows binary16
    let frame = proto::encode_snapshot_block(1, &[0.0], &block);
    proto::decode_from_worker_c(&frame, &rc).unwrap();

    // q8: NaN → reference, ±Inf saturate to ±127 quanta; the scale comes
    // from the finite deltas only
    let rc = RowCodec::new(Compression::Q8, &reference);
    let mut rows = vec![vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 2.0f32]];
    let block = codec::transform_rows(&rc, &mut rows).unwrap();
    let scale = 2.0f32 / 127.0;
    let r = &rows[0];
    assert_eq!(r[0].to_bits(), 0.0f32.to_bits());
    assert_eq!(r[1], 127.0 * scale);
    assert_eq!(r[2], -127.0 * scale);
    assert_eq!(r[3], 127.0 * scale);
    let frame = proto::encode_pull_reply_block(1, &block);
    proto::decode_peer_c(&frame, &rc).unwrap();
}

#[test]
fn none_block_framing_matches_legacy_bytes_exactly() {
    // the compression = none acceptance pin at the frame level: the
    // block-based encoders reproduce the v3 byte streams bit for bit,
    // and the none transform is the identity
    let rows = vec![vec![0.5f32, -1.5], vec![2.0, 3.0]];
    let rc = RowCodec::none();
    let mut copy = rows.clone();
    let block = codec::transform_rows(&rc, &mut copy).unwrap();
    assert_eq!(bits32(&rows), bits32(&copy));
    assert_eq!(
        proto::encode_snapshot_block(4, &[1.0, 2.0], &block),
        proto::encode_snapshot(4, &[1.0, 2.0], &rows)
    );
    assert_eq!(
        proto::encode_pull_reply_block(4, &block),
        proto::encode_pull_reply(4, &rows)
    );
}

#[test]
fn compressed_block_truncation_and_corruption_error_cleanly() {
    let reference = [0.25f32, -0.25, 1.0];
    for comp in [Compression::F16, Compression::Q8] {
        let rc = RowCodec::new(comp, &reference);
        let mut rows = vec![vec![1.0f32, 2.0, 3.0], vec![-1.0, -2.0, -3.0]];
        let block = codec::transform_rows(&rc, &mut rows).unwrap();
        let frame = proto::encode_pull_reply_block(2, &block);
        proto::decode_peer_c(&frame, &rc).expect("full buffer decodes");
        for cut in 0..frame.len() {
            assert!(
                proto::decode_peer_c(&frame[..cut], &rc).is_err(),
                "{comp:?}: truncation at {cut} must error"
            );
        }
        // oversized rows claim: must error on the byte bound, not allocate
        let mut bad = frame.clone();
        bad[9..13].copy_from_slice(&(1u32 << 31).to_le_bytes());
        assert!(proto::decode_peer_c(&bad, &rc).is_err());
        // zero-width header with a huge row count
        let mut zw = frame.clone();
        zw[9..13].copy_from_slice(&u32::MAX.to_le_bytes());
        zw[13..17].copy_from_slice(&0u32.to_le_bytes());
        assert!(proto::decode_peer_c(&zw, &rc).is_err());
        // block width disagreeing with the round's reference vector
        let short = RowCodec::new(comp, &reference[..2]);
        assert!(proto::decode_peer_c(&frame, &short).is_err());
    }
}

#[test]
fn every_truncation_of_every_message_errors_cleanly() {
    let digest = HonestDigest {
        count: 1,
        mean: vec![0.5, 1.5],
        std: vec![0.1, 0.2],
        prev_mean: vec![-0.5, -1.5],
    };
    let resume = proto::WireResume {
        round: 3,
        wire_ref: vec![0.5, -0.5],
        params: vec![vec![1.0f32, 2.0]],
        momentum: vec![vec![-1.0f32, -2.0]],
        carried: vec![Some(vec![0.25f32, 0.75]), None],
    };
    let to_worker = [
        proto::encode_init("task = \"tiny\"", 0, 2, &proto::WireResume::default()),
        proto::encode_init("task = \"tiny\"", 0, 2, &resume),
        proto::encode_get_state(7),
        proto::encode_half_step(9),
        proto::encode_async_round(9, &[0, 1, 3]),
        proto::encode_aggregate(1, &digest, &[vec![1.0f32, 2.0], vec![3.0, 4.0]]),
        proto::encode_aggregate_routed(1, &digest, &[vec![0, 3], vec![2]]),
        proto::encode_peers(&[
            PeerEntry {
                start: 0,
                len: 4,
                addr: "unix:/tmp/a.sock".into(),
            },
            PeerEntry {
                start: 4,
                len: 4,
                addr: "tcp:127.0.0.1:4040".into(),
            },
        ]),
        proto::encode_shutdown(),
    ];
    for buf in &to_worker {
        proto::decode_to_worker(buf).expect("full buffer decodes");
        for cut in 0..buf.len() {
            assert!(
                proto::decode_to_worker(&buf[..cut]).is_err(),
                "truncation at {cut} must error"
            );
        }
    }
    let from_worker = [
        proto::encode_init_ok(0, 5, 3),
        proto::encode_snapshot(2, &[1.0, 2.0], &[vec![0.5f32], vec![1.5f32]]),
        proto::encode_round_done(2, &[0, 1], &[5, 5], 99, 4, &[vec![1.0f32], vec![2.0f32]]),
        proto::encode_state(
            3,
            &[vec![1.0f32], vec![2.0f32]],
            &[vec![-1.0f32], vec![-2.0f32]],
            &[Some(vec![0.5f32]), None],
        ),
        proto::encode_failed("boom"),
    ];
    for buf in &from_worker {
        proto::decode_from_worker(buf).expect("full buffer decodes");
        for cut in 0..buf.len() {
            assert!(
                proto::decode_from_worker(&buf[..cut]).is_err(),
                "truncation at {cut} must error"
            );
        }
    }
    let peer = [
        proto::encode_peer_hello(3, 1, "unix:/tmp/w3.sock"),
        proto::encode_pull_request(6, &[1, 2, 3]),
        proto::encode_pull_reply(6, &[vec![1.0f32, 2.0], vec![3.0, 4.0]]),
        proto::encode_peer_deny("nope"),
    ];
    for buf in &peer {
        proto::decode_peer(buf).expect("full buffer decodes");
        for cut in 0..buf.len() {
            assert!(
                proto::decode_peer(&buf[..cut]).is_err(),
                "truncation at {cut} must error"
            );
        }
    }
}

#[test]
fn adversarial_pull_reply_shapes_error_not_panic() {
    // oversized row block: the claimed rows×d blows past the buffer —
    // must error on the byte bound, not allocate
    let mut buf = proto::encode_pull_reply(1, &[vec![1.0f32]]);
    // rows count sits right after tag+round: claim 2^31 rows
    buf[9..13].copy_from_slice(&(1u32 << 31).to_le_bytes());
    assert!(proto::decode_peer(&buf).is_err());

    // zero-width rows with a huge row count sidestep the byte bound —
    // rejected explicitly
    let mut zw = Vec::new();
    zw.push(0x42u8); // PullReply tag
    zw.extend_from_slice(&9u64.to_le_bytes());
    zw.extend_from_slice(&u32::MAX.to_le_bytes()); // rows = 4G
    zw.extend_from_slice(&0u32.to_le_bytes()); // d = 0
    assert!(proto::decode_peer(&zw).is_err());

    // trailing garbage after a valid message is version skew: reject
    let mut padded = proto::encode_pull_request(2, &[1]);
    padded.push(0xEE);
    assert!(proto::decode_peer(&padded).is_err());

    // unknown peer tag
    assert!(proto::decode_peer(&[0x7F]).is_err());
}
