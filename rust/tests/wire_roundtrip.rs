//! Wire-codec guarantees behind the multi-process shard engine:
//!
//! * **encode ∘ decode = id**, bit-wise, for random shard payloads
//!   (`testkit::forall` over random h/d row blocks and f64 digest
//!   partials) — the property cross-process bit-identity rests on;
//! * **committed golden vectors**: the byte layout is pinned literally,
//!   so an accidental codec change breaks loudly instead of silently
//!   desyncing coordinator and workers;
//! * truncated or corrupt buffers decode to errors, never panics.

use rpel::attacks::HonestDigest;
use rpel::testkit::{forall, Gen};
use rpel::util::rng::Rng;
use rpel::wire::proto::{self, FromWorker, ToWorker, WireDigest};

fn bits32(rows: &[Vec<f32>]) -> Vec<Vec<u32>> {
    rows.iter()
        .map(|r| r.iter().map(|x| x.to_bits()).collect())
        .collect()
}

fn bits64(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Random shard snapshot: h in [1,6] nodes, d in [1,9] coords, values
/// spanning signs, magnitudes, and exact zeros.
fn snapshot_gen() -> Gen<(Vec<f64>, Vec<Vec<f32>>)> {
    Gen::plain(|rng: &mut Rng| {
        let h = 1 + rng.index(6);
        let d = 1 + rng.index(9);
        let losses: Vec<f64> = (0..h).map(|_| (rng.f64() - 0.5) * 1e3).collect();
        let halves: Vec<Vec<f32>> = (0..h)
            .map(|_| {
                (0..d)
                    .map(|_| {
                        let x = (rng.f32() - 0.5) * 2.0;
                        if x.abs() < 0.01 {
                            0.0
                        } else {
                            x * 10f32.powi(rng.index(7) as i32 - 3)
                        }
                    })
                    .collect()
            })
            .collect();
        (losses, halves)
    })
}

#[test]
fn snapshot_encode_decode_is_identity() {
    forall(300, 0xA11CE, snapshot_gen(), |(losses, halves)| {
        let buf = proto::encode_snapshot(41, losses, halves);
        match proto::decode_from_worker(&buf) {
            Ok(FromWorker::Snapshot {
                round,
                losses: l2,
                halves: h2,
            }) => round == 41 && bits64(losses) == bits64(&l2) && bits32(halves) == bits32(&h2),
            _ => false,
        }
    });
}

#[test]
fn aggregate_encode_decode_is_identity_with_f64_partials() {
    forall(300, 0xD16E57, snapshot_gen(), |(partials, halves)| {
        // reuse the generated f64 vector as digest partials
        let digest = HonestDigest {
            count: partials.len(),
            mean: partials.clone(),
            std: partials.iter().map(|x| x.abs()).collect(),
            prev_mean: partials.iter().map(|x| -x).collect(),
        };
        let buf = proto::encode_aggregate(7, &digest, halves);
        match proto::decode_to_worker(&buf) {
            Ok(ToWorker::Aggregate {
                round,
                digest: d2,
                halves: h2,
            }) => {
                round == 7
                    && d2.count == digest.count as u64
                    && bits64(&digest.mean) == bits64(&d2.mean)
                    && bits64(&digest.std) == bits64(&d2.std)
                    && bits64(&digest.prev_mean) == bits64(&d2.prev_mean)
                    && bits32(halves) == bits32(&h2)
            }
            _ => false,
        }
    });
}

#[test]
fn round_done_encode_decode_is_identity() {
    forall(200, 0xB0B, snapshot_gen(), |(_, params)| {
        let n = params.len();
        let byz: Vec<u32> = (0..n as u32).collect();
        let recv: Vec<u32> = (0..n as u32).map(|x| x * 3 + 1).collect();
        let buf = proto::encode_round_done(9, &byz, &recv, params);
        match proto::decode_from_worker(&buf) {
            Ok(FromWorker::RoundDone {
                round,
                byz_seen,
                received,
                params: p2,
            }) => round == 9 && byz_seen == byz && received == recv && bits32(params) == bits32(&p2),
            _ => false,
        }
    });
}

// ---------------------------------------------------------------------------
// Golden vectors: the committed byte layout. If any of these fail, the
// wire format changed — bump PROTOCOL_VERSION and regenerate.
// ---------------------------------------------------------------------------

#[test]
fn golden_half_step() {
    let expect: [u8; 9] = [0x02, 3, 0, 0, 0, 0, 0, 0, 0];
    assert_eq!(proto::encode_half_step(3), expect);
    assert_eq!(
        proto::decode_to_worker(&expect).unwrap(),
        ToWorker::HalfStep { round: 3 }
    );
}

#[test]
fn golden_snapshot() {
    // round = 3, losses = [1.0f64], halves = [[1.0f32, -2.0f32]]
    let expect: [u8; 37] = [
        0x82, // tag
        3, 0, 0, 0, 0, 0, 0, 0, // round echo = 3
        0x01, 0x00, 0x00, 0x00, // 1 loss
        0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF0, 0x3F, // f64 1.0
        0x01, 0x00, 0x00, 0x00, // 1 row
        0x02, 0x00, 0x00, 0x00, // d = 2
        0x00, 0x00, 0x80, 0x3F, // f32 1.0
        0x00, 0x00, 0x00, 0xC0, // f32 -2.0
    ];
    let buf = proto::encode_snapshot(3, &[1.0f64], &[vec![1.0f32, -2.0f32]]);
    assert_eq!(buf, expect);
    match proto::decode_from_worker(&expect).unwrap() {
        FromWorker::Snapshot {
            round,
            losses,
            halves,
        } => {
            assert_eq!(round, 3);
            assert_eq!(losses, vec![1.0f64]);
            assert_eq!(halves, vec![vec![1.0f32, -2.0f32]]);
        }
        other => panic!("wrong message: {other:?}"),
    }
}

#[test]
fn golden_aggregate() {
    // round 5; digest: count=2, mean=[0.5], std=[], prev_mean=[-1.0];
    // halves = [[0.25f32]]
    let digest = HonestDigest {
        count: 2,
        mean: vec![0.5],
        std: vec![],
        prev_mean: vec![-1.0],
    };
    let expect: [u8; 57] = [
        0x03, // tag
        5, 0, 0, 0, 0, 0, 0, 0, // round = 5
        2, 0, 0, 0, 0, 0, 0, 0, // count = 2
        0x01, 0x00, 0x00, 0x00, // 1 mean coord
        0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xE0, 0x3F, // f64 0.5
        0x00, 0x00, 0x00, 0x00, // 0 std coords
        0x01, 0x00, 0x00, 0x00, // 1 prev-mean coord
        0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF0, 0xBF, // f64 -1.0
        0x01, 0x00, 0x00, 0x00, // 1 row
        0x01, 0x00, 0x00, 0x00, // d = 1
        0x00, 0x00, 0x80, 0x3E, // f32 0.25
    ];
    let buf = proto::encode_aggregate(5, &digest, &[vec![0.25f32]]);
    assert_eq!(buf, expect);
    match proto::decode_to_worker(&expect).unwrap() {
        ToWorker::Aggregate {
            round,
            digest: d2,
            halves,
        } => {
            assert_eq!(round, 5);
            assert_eq!(
                d2,
                WireDigest {
                    count: 2,
                    mean: vec![0.5],
                    std: vec![],
                    prev_mean: vec![-1.0],
                }
            );
            assert_eq!(halves, vec![vec![0.25f32]]);
        }
        other => panic!("wrong message: {other:?}"),
    }
}

#[test]
fn golden_round_done() {
    let expect: [u8; 37] = [
        0x83, // tag
        5, 0, 0, 0, 0, 0, 0, 0, // round echo = 5
        0x01, 0x00, 0x00, 0x00, // 1 byz count
        0x01, 0x00, 0x00, 0x00, // byz_seen[0] = 1
        0x01, 0x00, 0x00, 0x00, // 1 recv count
        0x06, 0x00, 0x00, 0x00, // received[0] = 6
        0x01, 0x00, 0x00, 0x00, // 1 row
        0x01, 0x00, 0x00, 0x00, // d = 1
        0x00, 0x00, 0x20, 0x40, // f32 2.5
    ];
    let buf = proto::encode_round_done(5, &[1], &[6], &[vec![2.5f32]]);
    assert_eq!(buf, expect);
}

#[test]
fn golden_shutdown_and_init_ok() {
    assert_eq!(proto::encode_shutdown(), vec![0x04]);
    // InitOk: tag, version 1, start=3, len=4, d=10
    let expect: [u8; 29] = [
        0x81, // tag
        0x01, 0x00, 0x00, 0x00, // protocol version 1
        3, 0, 0, 0, 0, 0, 0, 0, // start
        4, 0, 0, 0, 0, 0, 0, 0, // len
        10, 0, 0, 0, 0, 0, 0, 0, // d
    ];
    assert_eq!(proto::encode_init_ok(3, 4, 10), expect);
}

#[test]
fn every_truncation_of_every_message_errors_cleanly() {
    let digest = HonestDigest {
        count: 1,
        mean: vec![0.5, 1.5],
        std: vec![0.1, 0.2],
        prev_mean: vec![-0.5, -1.5],
    };
    let to_worker = [
        proto::encode_init("task = \"tiny\"", 0, 2),
        proto::encode_half_step(9),
        proto::encode_aggregate(1, &digest, &[vec![1.0f32, 2.0], vec![3.0, 4.0]]),
        proto::encode_shutdown(),
    ];
    for buf in &to_worker {
        proto::decode_to_worker(buf).expect("full buffer decodes");
        for cut in 0..buf.len() {
            assert!(
                proto::decode_to_worker(&buf[..cut]).is_err(),
                "truncation at {cut} must error"
            );
        }
    }
    let from_worker = [
        proto::encode_init_ok(0, 5, 3),
        proto::encode_snapshot(2, &[1.0, 2.0], &[vec![0.5f32], vec![1.5f32]]),
        proto::encode_round_done(2, &[0, 1], &[5, 5], &[vec![1.0f32], vec![2.0f32]]),
        proto::encode_failed("boom"),
    ];
    for buf in &from_worker {
        proto::decode_from_worker(buf).expect("full buffer decodes");
        for cut in 0..buf.len() {
            assert!(
                proto::decode_from_worker(&buf[..cut]).is_err(),
                "truncation at {cut} must error"
            );
        }
    }
}
