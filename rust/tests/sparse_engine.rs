//! Sparse-activation engine tests: partial participation composed with
//! the `[async]` virtual-clock engine, dense vs virtual-node backend.
//!
//! Two guarantees are pinned here:
//!
//! * **backend equivalence** — under an async straggler scenario with
//!   participation < 1, the virtual-node backend (committed state as
//!   `(seed, delta log)`, lazily materialized) reproduces the dense
//!   engine bit for bit: losses, ledgers, and every committed model;
//! * **ledger honesty** — `active_per_round` is recomputed byte-exactly
//!   from the *public* `(seed, round, node, PARTICIPATE)` streams, the
//!   same way `rust/tests/message_accounting.rs` recomputes the
//!   delivered-message ledger from the pull streams. The engine cannot
//!   quietly activate a node the coin did not choose.

// Test/bench code may time things, read the environment, and build
// scratch hash tables (clippy.toml's disallowed lists guard src only;
// the rpel-lint pass likewise skips test code).
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use rpel::config::{ExperimentConfig, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::testkit::scenario::Scenario;
use rpel::util::rng::{stream_tag, Rng};
use std::collections::HashSet;

const N: usize = 14;
const B: usize = 2;
const S: usize = 6;
const ROUNDS: usize = 8;

fn base_cfg(name: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = format!("sparse_engine_{name}");
    cfg.n = N;
    cfg.b = B;
    cfg.topology = Topology::Epidemic { s: S };
    cfg.bhat = Some(2);
    cfg.attack = rpel::attacks::AttackKind::parse("alie").unwrap();
    cfg.rounds = ROUNDS;
    cfg.batch = 8;
    cfg.samples_per_node = 32;
    cfg.test_samples = 64;
    cfg.eval_every = 4;
    cfg.threads = 1;
    cfg
}

fn honest_ids(cfg: &ExperimentConfig) -> Vec<usize> {
    // adversary placement is seed-derived: a second construction from
    // the same config reproduces it exactly
    let byz: HashSet<usize> = Trainer::from_config(cfg)
        .unwrap()
        .byzantine_ids()
        .into_iter()
        .collect();
    (0..cfg.n).filter(|id| !byz.contains(id)).collect()
}

/// History + every committed model, read through the backend-agnostic
/// accessor (virtual backends keep the dense row tables empty).
fn run_collect(cfg: &ExperimentConfig) -> (rpel::metrics::History, Vec<Vec<u32>>) {
    let mut t = Trainer::from_config(cfg).unwrap();
    let hist = t.run().unwrap();
    let params: Vec<Vec<u32>> = (0..t.honest_count())
        .map(|i| t.committed_params(i).iter().map(|x| x.to_bits()).collect())
        .collect();
    (hist, params)
}

#[test]
fn async_straggler_scenario_virtual_matches_dense_bit_for_bit() {
    // the composition pin: [async] stragglers (carried stale rows, decay
    // schedules, quorum closes) on top of a 0.75-participation round —
    // the virtual backend must agree with the dense engine on every bit
    let scenario = Scenario::named("straggler_twopoint").unwrap();
    let mut dense = base_cfg("straggler_dense");
    scenario.apply(&mut dense).unwrap();
    dense.participation = 0.75;

    let mut vcfg = dense.clone();
    vcfg.name = "sparse_engine_straggler_virtual".into();
    vcfg.virtual_nodes = true;

    let (dh, dp) = run_collect(&dense);
    let (vh, vp) = run_collect(&vcfg);

    let bits64 = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits64(&dh.train_loss), bits64(&vh.train_loss));
    assert_eq!(dh.observed_byz_max, vh.observed_byz_max);
    assert_eq!(dh.delivered_per_round, vh.delivered_per_round);
    assert_eq!(dh.participation_per_round, vh.participation_per_round);
    assert_eq!(dh.staleness_hist, vh.staleness_hist);
    assert_eq!(dh.active_per_round, vh.active_per_round);
    assert_eq!(dh.evals.len(), vh.evals.len());
    for (ea, eb) in dh.evals.iter().zip(&vh.evals) {
        assert_eq!(ea.avg_acc.to_bits(), eb.avg_acc.to_bits());
        assert_eq!(ea.avg_loss.to_bits(), eb.avg_loss.to_bits());
    }
    assert_eq!(dp, vp, "committed models must agree bit for bit");
}

#[test]
fn participation_ledger_recomputes_from_the_public_stream() {
    // ledger honesty: the per-round active count equals an independent
    // recomputation from the raw counter-keyed PARTICIPATE coins — no
    // engine internals involved
    let mut cfg = base_cfg("ledger_dense");
    cfg.participation = 0.6;
    let ids = honest_ids(&cfg);
    let (hist, _) = run_collect(&cfg);

    assert_eq!(hist.active_per_round.len(), ROUNDS);
    for round in 0..ROUNDS {
        let expect = ids
            .iter()
            .filter(|&&id| {
                Rng::stream(cfg.seed, round as u64, id as u64, stream_tag::PARTICIPATE).f64()
                    < cfg.participation
            })
            .count() as u32;
        assert_eq!(
            hist.active_per_round[round], expect,
            "round {round}: active-set ledger mismatch"
        );
    }

    // the same coins drive the virtual backend's active set
    let mut vcfg = cfg.clone();
    vcfg.name = "sparse_engine_ledger_virtual".into();
    vcfg.virtual_nodes = true;
    let (vhist, _) = run_collect(&vcfg);
    assert_eq!(hist.active_per_round, vhist.active_per_round);
}

#[test]
fn sparse_ledgers_are_consistent_and_virtual_stays_lean() {
    let mut dense = base_cfg("consistency_dense");
    dense.participation = 0.5;
    let mut vcfg = dense.clone();
    vcfg.name = "sparse_engine_consistency_virtual".into();
    vcfg.virtual_nodes = true;

    let (dh, _) = run_collect(&dense);
    let (vh, _) = run_collect(&vcfg);
    let h = (N - B) as u32;

    for hist in [&dh, &vh] {
        assert_eq!(hist.materialized_per_round.len(), ROUNDS);
        assert_eq!(hist.resident_bytes_per_round.len(), ROUNDS);
        for round in 0..ROUNDS {
            assert!(hist.active_per_round[round] <= h);
            assert!(hist.materialized_per_round[round] >= hist.active_per_round[round]);
            assert!(hist.resident_bytes_per_round[round] > 0);
        }
    }
    // dense always materializes everyone; virtual only touches the
    // active set plus the rows its victims pulled
    assert!(dh.materialized_per_round.iter().all(|&m| m == h));
    assert!(vh.materialized_per_round.iter().all(|&m| m <= h));

    // full-participation dense runs keep the sparse ledgers empty
    let (full, _) = run_collect(&base_cfg("full_dense"));
    assert!(full.active_per_round.is_empty());
    assert!(full.materialized_per_round.is_empty());
    assert!(full.resident_bytes_per_round.is_empty());
}
