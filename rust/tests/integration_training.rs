//! End-to-end coordinator integration: full training runs across engines,
//! attacks, aggregation rules, and topologies — asserting the paper's
//! qualitative claims at tiny scale.

use rpel::aggregation::gossip::GossipRuleKind;
use rpel::aggregation::RuleKind;
use rpel::attacks::AttackKind;
use rpel::config::presets::{self, Scale};
use rpel::config::{EngineKind, ExperimentConfig, RuleChoice, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::runtime::artifacts_available;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.n = 12;
    cfg.b = 2;
    cfg.topology = Topology::Epidemic { s: 6 };
    cfg.bhat = Some(2);
    cfg.rounds = 30;
    cfg.batch = 8;
    cfg.samples_per_node = 64;
    cfg.test_samples = 192;
    cfg.eval_every = 10;
    cfg.engine = EngineKind::Native;
    cfg.artifacts_dir = artifacts_dir();
    cfg
}

fn run(cfg: &ExperimentConfig) -> rpel::metrics::History {
    Trainer::from_config(cfg).unwrap().run().unwrap()
}

#[test]
fn attack_free_baseline_learns_well() {
    let mut cfg = base_cfg();
    cfg.b = 0;
    cfg.attack = AttackKind::None;
    let hist = run(&cfg);
    assert!(hist.final_avg_accuracy() > 0.75, "{}", hist.final_avg_accuracy());
}

#[test]
fn rpel_robust_under_every_attack() {
    // the paper's core claim (figs 1–2): NNM∘CWTM keeps accuracy close to
    // the attack-free run under all attacks
    let mut clean = base_cfg();
    clean.attack = AttackKind::None;
    let reference = run(&clean).final_avg_accuracy();
    for attack in AttackKind::panel() {
        let mut cfg = base_cfg();
        cfg.attack = attack;
        cfg.name = format!("robust/{}", attack.name());
        let acc = run(&cfg).final_avg_accuracy();
        assert!(
            acc > reference - 0.15,
            "{attack:?}: robust acc {acc} vs reference {reference}"
        );
    }
}

#[test]
fn plain_mean_collapses_under_strong_attacks() {
    // the non-robust baseline must fail visibly — otherwise the attacks
    // are toothless and the robustness claims vacuous
    let mut clean = base_cfg();
    clean.attack = AttackKind::None;
    let reference = run(&clean).final_avg_accuracy();
    let mut worst_drop = 0.0f64;
    for attack in [AttackKind::SignFlip, AttackKind::Dissensus, AttackKind::Alie] {
        let mut cfg = base_cfg();
        cfg.rule = RuleChoice::Epidemic(RuleKind::Mean);
        cfg.attack = attack;
        cfg.name = format!("mean/{}", attack.name());
        let acc = run(&cfg).final_avg_accuracy();
        worst_drop = worst_drop.max(reference - acc);
    }
    // the synthetic tiny task is easy enough that the mean partially
    // recovers; a >0.12 drop is still a clear, repeatable degradation the
    // robust rule does not show (see rpel_robust_under_every_attack)
    assert!(
        worst_drop > 0.12,
        "no attack hurt the plain mean (max drop {worst_drop:.3} from {reference:.3})"
    );
}

#[test]
fn all_epidemic_rules_survive_alie() {
    for rule in [
        RuleKind::CwTm,
        RuleKind::CwMed,
        RuleKind::NnmCwtm,
        RuleKind::NnmCwMed,
        RuleKind::GeoMedian,
    ] {
        let mut cfg = base_cfg();
        cfg.rule = RuleChoice::Epidemic(rule);
        cfg.attack = AttackKind::Alie;
        cfg.name = format!("rule/{}", rule.name());
        let hist = run(&cfg);
        assert!(
            hist.final_avg_accuracy() > 0.5,
            "{}: acc {}",
            rule.name(),
            hist.final_avg_accuracy()
        );
    }
}

#[test]
fn fixed_graph_baselines_run_and_resist() {
    for rule in [
        GossipRuleKind::CsPlus,
        GossipRuleKind::ClippedGossip,
        GossipRuleKind::Gts,
        GossipRuleKind::Rtc,
    ] {
        let mut cfg = base_cfg();
        cfg.topology = Topology::FixedGraph { edges: 36 };
        cfg.rule = RuleChoice::Gossip(rule);
        cfg.attack = AttackKind::Alie;
        cfg.name = format!("gossip/{}", rule.name());
        let hist = run(&cfg);
        assert!(
            hist.final_avg_accuracy() > 0.3,
            "{}: acc {}",
            rule.name(),
            hist.final_avg_accuracy()
        );
    }
}

#[test]
fn epidemic_beats_fixed_graph_at_same_budget() {
    // figs 4–7 at tiny scale: same message budget, ALIE attack, worst-case
    // client comparison (the paper's fairness headline)
    let s = 4usize;
    let mut rpel_cfg = base_cfg();
    rpel_cfg.topology = Topology::Epidemic { s };
    rpel_cfg.bhat = None; // Algorithm 2
    rpel_cfg.attack = AttackKind::Alie;
    rpel_cfg.rounds = 40;
    let rpel_hist = run(&rpel_cfg);

    let mut gossip_cfg = base_cfg();
    gossip_cfg.topology = Topology::FixedGraph {
        edges: rpel_cfg.n * s / 2,
    };
    gossip_cfg.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
    gossip_cfg.attack = AttackKind::Alie;
    gossip_cfg.rounds = 40;
    let gossip_hist = run(&gossip_cfg);

    assert_eq!(
        rpel_hist.messages_per_round,
        gossip_hist.messages_per_round
    );
    assert!(
        rpel_hist.final_worst_accuracy() >= gossip_hist.final_worst_accuracy() - 0.05,
        "rpel worst {} vs cs+ worst {}",
        rpel_hist.final_worst_accuracy(),
        gossip_hist.final_worst_accuracy()
    );
}

#[test]
fn local_steps_accelerate_convergence() {
    // §C.3: 3 local steps converge faster per round
    let mut one = base_cfg();
    one.attack = AttackKind::None;
    one.b = 0;
    one.rounds = 10;
    let acc1 = run(&one).final_avg_accuracy();
    let mut three = one.clone();
    three.local_steps = 3;
    let acc3 = run(&three).final_avg_accuracy();
    assert!(acc3 > acc1 - 0.02, "local=3 {acc3} vs local=1 {acc1}");
}

#[test]
fn hlo_engine_full_run_matches_quality() {
    if !artifacts_available(artifacts_dir()) {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut cfg = presets::quickstart_config();
    cfg.artifacts_dir = artifacts_dir();
    cfg.rounds = 25;
    cfg.engine = EngineKind::Hlo;
    let mut trainer = Trainer::from_config(&cfg).unwrap();
    // the production path must use the Pallas executable
    assert_eq!(trainer.aggregation_name(), "nnm_cwtm[pallas]");
    let hlo_hist = trainer.run().unwrap();

    cfg.engine = EngineKind::Native;
    let native_hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
    // engines differ in init (jax vs native RNG) but must reach the same
    // quality band on this separable task
    assert!(
        (hlo_hist.final_avg_accuracy() - native_hist.final_avg_accuracy()).abs() < 0.2,
        "hlo {} vs native {}",
        hlo_hist.final_avg_accuracy(),
        native_hist.final_avg_accuracy()
    );
    assert!(hlo_hist.final_avg_accuracy() > 0.6);
}

#[test]
fn figure_presets_run_at_reduced_rounds() {
    // every training figure's first series must construct and run
    for fig in presets::all_figures() {
        if let presets::FigureSeries::Training(mut cfgs) = fig.series(Scale::Tiny) {
            let cfg = &mut cfgs[0];
            cfg.rounds = 3;
            cfg.eval_every = 3;
            cfg.samples_per_node = 32;
            cfg.test_samples = 64;
            cfg.engine = EngineKind::Native;
            let hist = Trainer::from_config(cfg)
                .unwrap_or_else(|e| panic!("{}: {e}", cfg.name))
                .run()
                .unwrap();
            assert_eq!(hist.train_loss.len(), 3, "{}", cfg.name);
        }
    }
}

#[test]
fn breakdown_beyond_half_eaf_rejected() {
    // §6.2: beyond EAF 1/2 robust aggregation cannot exist — the trainer
    // must refuse rather than silently run
    let mut cfg = base_cfg();
    cfg.n = 12;
    cfg.b = 5;
    cfg.topology = Topology::Epidemic { s: 6 };
    cfg.bhat = None;
    cfg.rounds = 50;
    assert!(Trainer::from_config(&cfg).is_err());
}
