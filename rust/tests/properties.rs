//! Property-based tests (own `testkit` harness): the invariants behind the
//! paper's Definition 5.1 and the coordinator's routing/batching/state
//! contracts, over randomized inputs.

use rpel::aggregation::{pairwise_sqdist, RuleKind};
use rpel::coordinator::PullSampler;
use rpel::data::{partition_dirichlet, Shard, TaskKind};
use rpel::graph::Graph;
use rpel::sampling::Hypergeometric;
use rpel::testkit::{forall, Gen};
use rpel::util::rng::Rng;

fn random_rows(rng: &mut Rng, m: usize, d: usize, scale: f32) -> Vec<Vec<f32>> {
    (0..m)
        .map(|_| (0..d).map(|_| rng.gaussian32(0.0, scale)).collect())
        .collect()
}

/// Definition 5.1 sampled empirically: for honest-only inputs U = [m],
/// ||R(v) − v̄||² ≤ κ/m Σ ||v_i − v̄||² must hold with a κ well below the
/// 1/6-threshold the convergence analysis needs (Lemma 5.2 remark),
/// for the paper's rule NNM∘CWTM at b̂/m ≤ 1/3.
#[test]
fn prop_nnm_cwtm_kappa_bound() {
    forall(60, 0xD501, Gen::usize_in(0..=10_000), |&seed| {
        let mut rng = Rng::new(seed as u64);
        let m = 6 + rng.index(12); // 6..17
        let b = (m - 1) / 3;
        let d = 1 + rng.index(40);
        let rows = random_rows(&mut rng, m, d, 2.0);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let rule = RuleKind::NnmCwtm.build(b);
        let mut out = vec![0.0f32; d];
        rule.aggregate(&refs, &mut out);

        let mut vbar = vec![0.0f64; d];
        for r in &rows {
            for (a, &x) in vbar.iter_mut().zip(r.iter()) {
                *a += x as f64 / m as f64;
            }
        }
        let err: f64 = out
            .iter()
            .zip(&vbar)
            .map(|(&o, &v)| (o as f64 - v) * (o as f64 - v))
            .sum();
        let var: f64 = rows
            .iter()
            .map(|r| {
                r.iter()
                    .zip(&vbar)
                    .map(|(&x, &v)| (x as f64 - v) * (x as f64 - v))
                    .sum::<f64>()
            })
            .sum::<f64>()
            / m as f64;
        // κ must be at most ~2·b/m here; use 1.0 as the hard invariant
        err <= var.max(1e-12)
    });
}

/// Permutation invariance of every Definition-5.1 rule.
#[test]
fn prop_rules_permutation_invariant() {
    for kind in [
        RuleKind::Mean,
        RuleKind::CwTm,
        RuleKind::CwMed,
        RuleKind::NnmCwtm,
        RuleKind::GeoMedian,
    ] {
        forall(40, 0x9E12 + kind.name().len() as u64, Gen::usize_in(0..=10_000), |&seed| {
            let mut rng = Rng::new(seed as u64);
            let m = 5 + rng.index(10);
            let b = (m - 1) / 3;
            let d = 1 + rng.index(20);
            let rows = random_rows(&mut rng, m, d, 5.0);
            let mut perm: Vec<usize> = (0..m).collect();
            rng.shuffle(&mut perm);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let permuted: Vec<&[f32]> = perm.iter().map(|&i| refs[i]).collect();
            let rule = kind.build(b);
            let mut a = vec![0.0f32; d];
            let mut p = vec![0.0f32; d];
            rule.aggregate(&refs, &mut a);
            rule.aggregate(&permuted, &mut p);
            a.iter().zip(&p).all(|(x, y)| (x - y).abs() <= 1e-4)
        });
    }
}

/// Translation equivariance: R(v + c) = R(v) + c for the coordinate-wise
/// and mixing rules (distance structure unchanged by translation).
#[test]
fn prop_translation_equivariance() {
    for kind in [RuleKind::Mean, RuleKind::CwTm, RuleKind::CwMed, RuleKind::NnmCwtm] {
        forall(40, 0x7A31, Gen::usize_in(0..=10_000), |&seed| {
            let mut rng = Rng::new(seed as u64);
            let m = 5 + rng.index(8);
            let b = (m - 1) / 3;
            let d = 1 + rng.index(12);
            let rows = random_rows(&mut rng, m, d, 3.0);
            let shift = rng.gaussian32(0.0, 10.0);
            let shifted: Vec<Vec<f32>> = rows
                .iter()
                .map(|r| r.iter().map(|x| x + shift).collect())
                .collect();
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let srefs: Vec<&[f32]> = shifted.iter().map(|r| r.as_slice()).collect();
            let rule = kind.build(b);
            let mut a = vec![0.0f32; d];
            let mut s = vec![0.0f32; d];
            rule.aggregate(&refs, &mut a);
            rule.aggregate(&srefs, &mut s);
            a.iter().zip(&s).all(|(x, y)| (x + shift - y).abs() <= 2e-3)
        });
    }
}

/// The pull sampler's contract: exact size, no self, no duplicates,
/// all within range — for every (n, s, victim).
#[test]
fn prop_sampler_contract() {
    forall(300, 0x5A91, Gen::usize_in(0..=100_000), |&seed| {
        let mut rng = Rng::new(seed as u64);
        let n = 2 + rng.index(60);
        let s = 1 + rng.index(n - 1);
        let victim = rng.index(n);
        let sampler = PullSampler::new(n, s);
        let sample = sampler.sample(victim, &mut rng);
        let mut sorted = sample.clone();
        sorted.sort_unstable();
        sorted.dedup();
        sample.len() == s
            && sorted.len() == s
            && !sample.contains(&victim)
            && sample.iter().all(|&x| x < n)
    });
}

/// Hypergeometric CDF is a valid monotone distribution for arbitrary
/// parameters.
#[test]
fn prop_hypergeometric_cdf_valid() {
    forall(200, 0x46EC, Gen::usize_in(0..=100_000), |&seed| {
        let mut rng = Rng::new(seed as u64);
        let total = 1 + rng.index(500) as u64;
        let marked = rng.index(total as usize + 1) as u64;
        let draws = rng.index(total as usize + 1) as u64;
        let hg = Hypergeometric::new(total, marked, draws);
        let mut prev = 0.0;
        for k in 0..=draws.min(marked) {
            let c = hg.cdf(k);
            if !(c >= prev - 1e-12 && (0.0..=1.0 + 1e-12).contains(&c)) {
                return false;
            }
            prev = c;
        }
        (hg.cdf(draws.min(marked)) - 1.0).abs() < 1e-9
    });
}

/// Dirichlet partitioning: exact shard sizes and in-range labels for any
/// (nodes, classes, alpha).
#[test]
fn prop_dirichlet_partition_exact() {
    forall(60, 0xD112, Gen::usize_in(0..=10_000), |&seed| {
        let mut rng = Rng::new(seed as u64);
        let nodes = 1 + rng.index(40);
        let classes = 2 + rng.index(30);
        let spn = 1 + rng.index(100);
        let alpha = 0.1 + rng.f64() * 20.0;
        let shards = partition_dirichlet(nodes, classes, spn, alpha, &mut rng);
        shards.len() == nodes
            && shards.iter().all(|s| {
                s.len() == spn && s.iter().all(|&y| (0..classes as i32).contains(&y))
            })
    });
}

/// Random connected graphs: connected, right edge count, no self-loops.
#[test]
fn prop_graph_generator() {
    forall(80, 0x6EA9, Gen::usize_in(0..=10_000), |&seed| {
        let mut rng = Rng::new(seed as u64);
        let n = 2 + rng.index(40);
        let max_edges = n * (n - 1) / 2;
        let target = (n - 1) + rng.index(max_edges - (n - 1) + 1);
        let g = Graph::random_connected(n, target, &mut rng);
        g.is_connected()
            && g.edges == target
            && (0..n).all(|i| !g.neighbors(i).contains(&i))
    });
}

/// Batch iterator: exact sizes forever, even when batch > shard size.
#[test]
fn prop_shard_batching() {
    forall(60, 0xBA7C, Gen::usize_in(0..=10_000), |&seed| {
        let mut rng = Rng::new(seed as u64);
        let n = 1 + rng.index(50);
        let batch = 1 + rng.index(2 * n);
        let inst = TaskKind::Tiny.spec().instantiate(seed as u64);
        let data = inst.sample_uniform(n, &mut rng);
        let mut shard = Shard::new(data, Rng::new(seed as u64 + 1));
        (0..5).all(|_| {
            let b = shard.next_batch(batch);
            b.y.len() == batch && b.x.len() == batch * 16
        })
    });
}

/// Distance matrix: symmetric, zero diagonal, non-negative.
#[test]
fn prop_pairwise_distances() {
    forall(100, 0xD157, Gen::usize_in(0..=10_000), |&seed| {
        let mut rng = Rng::new(seed as u64);
        let m = 2 + rng.index(12);
        let d = 1 + rng.index(30);
        let rows = random_rows(&mut rng, m, d, 100.0);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let dist = pairwise_sqdist(&refs);
        (0..m).all(|i| {
            dist[i * m + i] == 0.0
                && (0..m).all(|j| dist[i * m + j] >= 0.0 && dist[i * m + j] == dist[j * m + i])
        })
    });
}
