//! Differential tests: Rust-native aggregators and the native MLP engine
//! vs the Python/jnp oracle fixtures emitted by `python/compile/aot.py`.
//!
//! Skipped (with a notice) when artifacts have not been built.

use rpel::aggregation::{CwMed, CwTm, GeoMedian, Krum, Mean, Nnm};
use rpel::aggregation::Aggregator;
use rpel::model::MlpSpec;
use rpel::util::json::{self, Json};

fn fixtures_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/fixtures");
    dir.exists().then_some(dir)
}

fn load(name: &str) -> Option<Json> {
    let path = fixtures_dir()?.join(name);
    let text = std::fs::read_to_string(path).ok()?;
    Some(json::parse(&text).expect("fixture must be valid JSON"))
}

fn rows(x: &[f32], m: usize, d: usize) -> Vec<&[f32]> {
    (0..m).map(|i| &x[i * d..(i + 1) * d]).collect()
}

fn assert_close(got: &[f32], want: &[f32], tol: f64, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let denom = w.abs().max(1.0) as f64;
        assert!(
            ((g - w).abs() as f64) / denom < tol,
            "{what}[{i}]: got {g}, oracle {w}"
        );
    }
}

#[test]
fn aggregation_rules_match_jnp_oracle() {
    let Some(fx) = load("agg_fixtures.json") else {
        eprintln!("skipping: run `make artifacts` to emit fixtures");
        return;
    };
    let cases = fx.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 8);
    let mut checked = 0;
    for case in cases {
        let m = case.get("m").unwrap().as_usize().unwrap();
        let d = case.get("d").unwrap().as_usize().unwrap();
        let b = case.get("b").unwrap().as_usize().unwrap();
        let x = case.get("x").unwrap().as_f32_vec().unwrap();
        let inputs = rows(&x, m, d);
        let mut out = vec![0.0f32; d];
        let tag = format!("m={m} d={d} b={b}");

        let want = case.get("mean").unwrap().as_f32_vec().unwrap();
        Mean.aggregate(&inputs, &mut out);
        assert_close(&out, &want, 1e-4, &format!("mean {tag}"));

        let want = case.get("cwmed").unwrap().as_f32_vec().unwrap();
        CwMed.aggregate(&inputs, &mut out);
        assert_close(&out, &want, 1e-4, &format!("cwmed {tag}"));

        if let Some(want) = case.get("cwtm").map(|v| v.as_f32_vec().unwrap()) {
            CwTm::new(b).aggregate(&inputs, &mut out);
            assert_close(&out, &want, 1e-4, &format!("cwtm {tag}"));
        }
        if let Some(want) = case.get("nnm").map(|v| v.as_f32_vec().unwrap()) {
            let mut mixed = Vec::new();
            Nnm::new(b, Mean).mix_into(&inputs, &mut mixed);
            assert_close(&mixed, &want, 1e-4, &format!("nnm-mix {tag}"));
        }
        if let Some(want) = case.get("nnm_cwtm").map(|v| v.as_f32_vec().unwrap()) {
            Nnm::new(b, CwTm::new(b)).aggregate(&inputs, &mut out);
            assert_close(&out, &want, 1e-4, &format!("nnm_cwtm {tag}"));
        }
        if let Some(want) = case.get("krum").map(|v| v.as_f32_vec().unwrap()) {
            Krum::new(b).aggregate(&inputs, &mut out);
            assert_close(&out, &want, 1e-4, &format!("krum {tag}"));
        }
        if let Some(want) = case.get("geomedian").map(|v| v.as_f32_vec().unwrap()) {
            GeoMedian::default().aggregate(&inputs, &mut out);
            assert_close(&out, &want, 5e-3, &format!("geomedian {tag}"));
        }
        checked += 1;
    }
    assert!(checked >= 8, "checked only {checked} fixture cases");
}

#[test]
fn native_mlp_matches_jax_forward() {
    let Some(fx) = load("model_fixtures.json") else {
        eprintln!("skipping: run `make artifacts` to emit fixtures");
        return;
    };
    for case in fx.get("cases").unwrap().as_arr().unwrap() {
        let arch = case.get("arch").unwrap().as_str().unwrap();
        let spec = MlpSpec::by_name(arch).expect("fixture arch must exist natively");
        let d = case.get("d").unwrap().as_usize().unwrap();
        assert_eq!(
            spec.param_count(),
            d,
            "{arch}: native param layout disagrees with ravel_pytree"
        );
        let params = case.get("params").unwrap().as_f32_vec().unwrap();
        let x = case.get("x").unwrap().as_f32_vec().unwrap();
        let y: Vec<i32> = case
            .get("y")
            .unwrap()
            .as_i64_vec()
            .unwrap()
            .into_iter()
            .map(|v| v as i32)
            .collect();
        let n = case.get("n").unwrap().as_usize().unwrap();

        // forward log-probs must match jax within f32 tolerance
        let want_logp = case.get("logp").unwrap().as_f32_vec().unwrap();
        let mut logp = Vec::new();
        spec.forward(&params, &x, n, &mut logp);
        assert_close(&logp, &want_logp, 1e-4, &format!("{arch} logp"));

        // eval counters
        let want_correct = case.get("correct").unwrap().as_f64().unwrap();
        let want_loss = case.get("loss_sum").unwrap().as_f64().unwrap();
        let (correct, loss) = spec.evaluate(&params, &x, &y);
        assert_eq!(correct, want_correct, "{arch} correct-count");
        assert!(
            (loss - want_loss).abs() / want_loss.abs().max(1.0) < 1e-4,
            "{arch} loss: got {loss}, oracle {want_loss}"
        );
    }
}
