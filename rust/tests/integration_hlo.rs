//! Integration tests over the PJRT runtime: the HLO executables must agree
//! with the native twin and satisfy their interface contracts.
//!
//! These tests skip (with a notice) when `make artifacts` has not run.

use rpel::aggregation::{Aggregator, CwTm, Nnm};
use rpel::model::native::{MlpSpec, TrainHyper};
use rpel::runtime::{artifacts_available, Runtime};
use rpel::util::rng::Rng;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

macro_rules! require_artifacts {
    () => {{
        let dir = artifacts_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        Runtime::open(&dir).expect("artifacts dir must load")
    }};
}

#[test]
fn manifest_inventory_complete() {
    let rt = require_artifacts!();
    let m = rt.manifest();
    for arch in ["mlp_tiny", "mlp_mnistlike", "mlp_cifarlike", "mlp_femnistlike"] {
        assert!(m.find(|e| e.kind == "init" && e.arch == arch).is_some(), "{arch} init");
        assert!(m.find(|e| e.kind == "train" && e.arch == arch).is_some(), "{arch} train");
        assert!(m.find(|e| e.kind == "eval" && e.arch == arch).is_some(), "{arch} eval");
        assert!(
            m.find(|e| e.kind == "aggregate" && e.arch == arch).is_some(),
            "{arch} aggregate"
        );
        // native layout must agree with the jax flat codec
        let native = MlpSpec::by_name(arch).unwrap().param_count();
        assert_eq!(m.param_count(arch), Some(native), "{arch} d");
    }
    // local-steps variants for the figures that need them
    assert!(m
        .find(|e| e.kind == "train" && e.arch == "mlp_cifarlike" && e.local_steps == 3)
        .is_some());
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let mut rt = require_artifacts!();
    let init = rt.init_exec("mlp_tiny").unwrap();
    let a = init.run(7).unwrap();
    let b = init.run(7).unwrap();
    let c = init.run(8).unwrap();
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert!(a.iter().all(|v| v.is_finite()));
}

#[test]
fn hlo_train_step_matches_native_engine() {
    let mut rt = require_artifacts!();
    let init = rt.init_exec("mlp_tiny").unwrap();
    let train = rt.train_exec("mlp_tiny", 1).unwrap();
    let spec = MlpSpec::by_name("mlp_tiny").unwrap();

    let params0 = init.run(3).unwrap();
    let momentum0 = vec![0.01f32; params0.len()];
    let mut rng = Rng::new(11);
    let batch = train.entry.batch;
    let din = 16;
    let x: Vec<f32> = (0..batch * din).map(|_| rng.gaussian32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.index(4) as i32).collect();
    let (lr, beta, wd) = (0.1f32, 0.9f32, 1e-3f32);

    let out = train.run(&params0, &momentum0, &x, &y, lr, beta, wd).unwrap();

    let mut np = params0.clone();
    let mut nm = momentum0.clone();
    let mut scratch = Vec::new();
    let nloss = spec.train_step(
        &mut np,
        &mut nm,
        &x,
        &y,
        TrainHyper { lr, beta, weight_decay: wd },
        &mut scratch,
    );

    assert!(
        (out.loss - nloss).abs() < 1e-4,
        "loss: hlo={} native={nloss}",
        out.loss
    );
    for i in 0..np.len() {
        assert!(
            (out.params[i] - np[i]).abs() < 1e-4,
            "params[{i}]: hlo={} native={}",
            out.params[i],
            np[i]
        );
        assert!(
            (out.momentum[i] - nm[i]).abs() < 1e-4,
            "momentum[{i}]: hlo={} native={}",
            out.momentum[i],
            nm[i]
        );
    }
}

#[test]
fn hlo_eval_matches_native() {
    let mut rt = require_artifacts!();
    let init = rt.init_exec("mlp_tiny").unwrap();
    let eval = rt.eval_exec("mlp_tiny").unwrap();
    let spec = MlpSpec::by_name("mlp_tiny").unwrap();

    let params = init.run(0).unwrap();
    let n = eval.eval_n();
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..n * 16).map(|_| rng.gaussian32(0.0, 2.0)).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.index(4) as i32).collect();

    let (hc, hl) = eval.run(&params, &x, &y).unwrap();
    let (nc, nl) = spec.evaluate(&params, &x, &y);
    assert_eq!(hc, nc, "correct-count must match exactly");
    assert!((hl - nl).abs() / nl.max(1.0) < 1e-4, "loss: hlo={hl} native={nl}");
}

#[test]
fn pallas_aggregate_matches_native_rule() {
    let mut rt = require_artifacts!();
    let agg = rt.aggregate_exec("mlp_tiny", 8, 2).unwrap();
    let d = agg.entry.d;
    let mut rng = Rng::new(9);
    // mixed-magnitude inputs including adversarial-scale rows
    let rows: Vec<Vec<f32>> = (0..8)
        .map(|i| {
            let scale = if i >= 6 { 1e4 } else { 1.0 };
            (0..d).map(|_| rng.gaussian32(0.0, 1.0) * scale).collect()
        })
        .collect();
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();

    let hlo_out = agg.run(&refs).unwrap();
    let mut native_out = vec![0.0f32; d];
    Nnm::new(2, CwTm::new(2)).aggregate(&refs, &mut native_out);

    for i in 0..d {
        assert!(
            (hlo_out[i] - native_out[i]).abs() < 1e-3,
            "agg[{i}]: pallas={} native={}",
            hlo_out[i],
            native_out[i]
        );
    }
}

#[test]
fn aggregate_shape_contract_enforced() {
    let mut rt = require_artifacts!();
    let agg = rt.aggregate_exec("mlp_tiny", 8, 2).unwrap();
    let d = agg.entry.d;
    let rows: Vec<Vec<f32>> = (0..7).map(|_| vec![0.0f32; d]).collect();
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    // 7 rows into an m=8 executable must fail loudly, not truncate
    assert!(agg.run(&refs).is_err());
    let bad: Vec<Vec<f32>> = (0..8).map(|_| vec![0.0f32; d - 1]).collect();
    let refs: Vec<&[f32]> = bad.iter().map(|r| r.as_slice()).collect();
    assert!(agg.run(&refs).is_err());
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let mut rt = require_artifacts!();
    let err = match rt.aggregate_exec("mlp_tiny", 31, 15) {
        Ok(_) => panic!("expected missing-artifact error"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("no aggregate artifact"), "{err}");
    let err = match rt.train_exec("resnet", 1) {
        Ok(_) => panic!("expected missing-artifact error"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("no train artifact"), "{err}");
}

#[test]
fn local_steps_hlo_equals_sequential_native() {
    let mut rt = require_artifacts!();
    let Ok(train3) = rt.train_exec("mlp_cifarlike", 3) else {
        eprintln!("skipping: no k=3 artifact");
        return;
    };
    let init = rt.init_exec("mlp_cifarlike").unwrap();
    let spec = MlpSpec::by_name("mlp_cifarlike").unwrap();
    let params0 = init.run(1).unwrap();
    let momentum0 = vec![0.0f32; params0.len()];
    let batch = train3.entry.batch;
    let din = 96;
    let mut rng = Rng::new(13);
    let x: Vec<f32> = (0..3 * batch * din).map(|_| rng.gaussian32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..3 * batch).map(|_| rng.index(10) as i32).collect();
    let (lr, beta, wd) = (0.05f32, 0.99f32, 1e-2f32);

    let out = train3.run(&params0, &momentum0, &x, &y, lr, beta, wd).unwrap();

    let mut np = params0.clone();
    let mut nm = momentum0.clone();
    let mut scratch = Vec::new();
    for k in 0..3 {
        spec.train_step(
            &mut np,
            &mut nm,
            &x[k * batch * din..(k + 1) * batch * din],
            &y[k * batch..(k + 1) * batch],
            TrainHyper { lr, beta, weight_decay: wd },
            &mut scratch,
        );
    }
    let max_err = out
        .params
        .iter()
        .zip(&np)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 5e-4, "3-local-step drift {max_err}");
}
