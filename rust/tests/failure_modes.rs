//! Failure-injection and threat-model-boundary tests: pull-vs-push
//! (Appendix D flooding), DoS under the synchronous model, corrupt
//! artifacts, and observed-b̂ telemetry against the Algorithm-2 bound.

// Test/bench code may time things, read the environment, and build
// scratch hash tables (clippy.toml's disallowed lists guard src only;
// the rpel-lint pass likewise skips test code).
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use rpel::aggregation::RuleKind;
use rpel::attacks::AttackKind;
use rpel::config::{EngineKind, ExperimentConfig, RuleChoice, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::runtime::Runtime;

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.n = 12;
    cfg.b = 2;
    cfg.topology = Topology::Epidemic { s: 6 };
    cfg.bhat = Some(2);
    cfg.rounds = 30;
    cfg.batch = 8;
    cfg.samples_per_node = 64;
    cfg.test_samples = 192;
    cfg.eval_every = 10;
    cfg.engine = EngineKind::Native;
    cfg
}

#[test]
fn push_flooding_breaks_what_pull_survives() {
    // Appendix D / §3.3: in push mode the attackers flood every honest
    // node each round, so every victim receives all b malicious models
    // while its trim radius was calibrated to the pull-mode b̂ << b.
    // Pull caps the per-node exposure at the hypergeometric draw. Same
    // rule, same fan-in, opposite outcome.
    let mut pull = base_cfg();
    pull.n = 100;
    pull.b = 10; // the paper's fig1L geometry (10% Byzantine)
    pull.topology = Topology::Epidemic { s: 15 };
    pull.bhat = None; // resolves to b̂ = 7 (paper §6.2)
    pull.attack = AttackKind::SignFlip;
    pull.rounds = 30;
    pull.name = "pull/sf".into();
    let pull_hist = Trainer::from_config(&pull).unwrap().run().unwrap();

    let mut push = pull.clone();
    push.topology = Topology::EpidemicPush { s: 15 };
    push.name = "push/sf".into();
    let push_hist = Trainer::from_config(&push).unwrap().run().unwrap();

    // flooding delivers all b malicious rows to every victim ...
    assert_eq!(push_hist.observed_bhat(), 10);
    // ... while pull stays within the hypergeometric b̂ = 7
    assert!(pull_hist.observed_bhat() <= 7);
    // ... and the trim calibrated for b̂ = 7 collapses against 10 floods
    assert!(
        pull_hist.final_avg_accuracy() > push_hist.final_avg_accuracy() + 0.3,
        "pull {} should beat flooded push {}",
        pull_hist.final_avg_accuracy(),
        push_hist.final_avg_accuracy()
    );
}

#[test]
fn dos_is_neutralized_by_synchronous_pull() {
    // Appendix D: withholding responses cannot hurt beyond removing
    // inputs — accuracy stays close to the attack-free run
    let mut clean = base_cfg();
    clean.attack = AttackKind::None;
    let reference = Trainer::from_config(&clean)
        .unwrap()
        .run()
        .unwrap()
        .final_avg_accuracy();

    let mut dos = base_cfg();
    dos.attack = AttackKind::Dos;
    dos.name = "dos".into();
    let hist = Trainer::from_config(&dos).unwrap().run().unwrap();
    assert!(
        hist.final_avg_accuracy() > reference - 0.1,
        "DoS acc {} vs clean {reference}",
        hist.final_avg_accuracy()
    );
    // and nothing malicious was ever aggregated
    assert_eq!(hist.observed_bhat(), 0);
}

#[test]
fn observed_bhat_respects_algorithm2_bound() {
    // the whole point of §4.2: the realized max number of selected
    // attackers must stay at or below the Algorithm-2 b̂ (whp)
    let mut cfg = base_cfg();
    cfg.n = 20;
    cfg.b = 4;
    cfg.topology = Topology::Epidemic { s: 8 };
    cfg.bhat = None; // let Algorithm 2 pick
    cfg.rounds = 50;
    let mut trainer = Trainer::from_config(&cfg).unwrap();
    let predicted = trainer.bhat;
    let hist = trainer.run().unwrap();
    assert!(
        hist.observed_bhat() <= predicted,
        "observed b̂ {} exceeded Algorithm-2 prediction {predicted}",
        hist.observed_bhat()
    );
    // and the telemetry is not trivially zero
    assert!(hist.observed_bhat() >= 1);
}

#[test]
fn push_without_flood_uses_more_messages_for_same_s() {
    let mut pull = base_cfg();
    pull.topology = Topology::Epidemic { s: 6 };
    let mut push = base_cfg();
    push.topology = Topology::EpidemicPush { s: 6 };
    assert!(push.messages_per_round() > pull.messages_per_round() - 6 * 2);
}

#[test]
fn push_rejects_hlo_engine() {
    let mut cfg = base_cfg();
    cfg.topology = Topology::EpidemicPush { s: 6 };
    cfg.engine = EngineKind::Hlo;
    assert!(cfg.validate().unwrap_err().contains("push"));
}

#[test]
fn corrupt_artifact_fails_loudly() {
    let dir = std::env::temp_dir().join("rpel_corrupt_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    // well-formed manifest pointing at garbage HLO
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "scale": "test", "artifacts": [
            {"name": "init_x", "file": "init_x.hlo.txt", "kind": "init",
             "arch": "x", "d": 4, "input_shape": [2], "classes": 2}]}"#,
    )
    .unwrap();
    std::fs::write(dir.join("init_x.hlo.txt"), "this is not HLO").unwrap();
    let mut rt = Runtime::open(&dir).unwrap();
    let err = match rt.init_exec("x") {
        Ok(_) => panic!("corrupt HLO must not load"),
        Err(e) => format!("{e:#}"),
    };
    assert!(
        err.contains("init_x") || err.contains("parse"),
        "unhelpful error: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_artifacts_dir_is_actionable() {
    let err = match Runtime::open("/nonexistent/path") {
        Ok(_) => panic!(),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("make artifacts"), "{err}");
}

#[test]
fn truncated_manifest_rejected() {
    let dir = std::env::temp_dir().join("rpel_bad_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{\"version\": 1, \"artifac").unwrap();
    assert!(Runtime::open(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn dos_with_all_rules_stays_finite() {
    for rule in [RuleKind::Mean, RuleKind::CwTm, RuleKind::NnmCwtm, RuleKind::Krum] {
        let mut cfg = base_cfg();
        cfg.rule = RuleChoice::Epidemic(rule);
        cfg.attack = AttackKind::Dos;
        cfg.rounds = 10;
        cfg.name = format!("dos/{}", rule.name());
        let mut t = Trainer::from_config(&cfg).unwrap();
        t.run().unwrap();
        for i in 0..t.honest_count() {
            assert!(rpel::util::vecmath::all_finite(t.params_of(i)));
        }
    }
}
