//! Bit-level reproducibility of the round engine: the same config must
//! produce an identical `History` (and identical final models) on every
//! run — and, because all round-path randomness is counter-keyed per
//! `(seed, round, node)`, the attack digest is folded in global honest
//! order, and the wire codec ships IEEE bit patterns, for **every
//! (procs × shards × threads) combination** — including the
//! multi-process engine, whose shard workers live in separate `rpel
//! shard-worker` processes. These are exact comparisons, not tolerances:
//! the per-node RNG streams make this a hard guarantee, not a flake.

use rpel::aggregation::gossip::GossipRuleKind;
use rpel::attacks::AttackKind;
use rpel::config::RuleChoice;
use rpel::coordinator::Trainer;
use rpel::metrics::History;

fn base_cfg() -> rpel::config::ExperimentConfig {
    use rpel::config::{EngineKind, ExperimentConfig, Topology};
    use rpel::data::TaskKind;
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.n = 12;
    cfg.b = 2;
    cfg.topology = Topology::Epidemic { s: 6 };
    cfg.bhat = Some(2);
    cfg.attack = AttackKind::Alie;
    cfg.rounds = 10;
    cfg.batch = 8;
    cfg.samples_per_node = 48;
    cfg.test_samples = 96;
    cfg.eval_every = 5;
    cfg.engine = EngineKind::Native;
    cfg
}

/// Run a config and collect everything comparable: history + final models.
fn run_collect(cfg: &rpel::config::ExperimentConfig) -> (History, Vec<Vec<f32>>) {
    let mut t = Trainer::from_config(cfg).unwrap();
    let hist = t.run().unwrap();
    let params: Vec<Vec<f32>> = (0..t.honest_count())
        .map(|i| t.params_of(i).to_vec())
        .collect();
    (hist, params)
}

/// Exact (bit-level) equality of two runs, ignoring only wall_secs.
fn assert_bit_identical(label: &str, a: &(History, Vec<Vec<f32>>), b: &(History, Vec<Vec<f32>>)) {
    let bits64 = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits64(&a.0.train_loss),
        bits64(&b.0.train_loss),
        "{label}: train_loss"
    );
    assert_eq!(
        a.0.observed_byz_max, b.0.observed_byz_max,
        "{label}: observed_byz_max"
    );
    assert_eq!(a.0.total_messages, b.0.total_messages, "{label}: messages");
    assert_eq!(
        a.0.delivered_per_round, b.0.delivered_per_round,
        "{label}: delivered_per_round"
    );
    assert_eq!(
        a.0.total_delivered, b.0.total_delivered,
        "{label}: total_delivered"
    );
    assert_eq!(a.0.evals.len(), b.0.evals.len(), "{label}: eval count");
    for (ea, eb) in a.0.evals.iter().zip(&b.0.evals) {
        assert_eq!(ea.round, eb.round, "{label}: eval round");
        assert_eq!(
            ea.avg_acc.to_bits(),
            eb.avg_acc.to_bits(),
            "{label}: avg_acc @ {}",
            ea.round
        );
        assert_eq!(
            ea.worst_acc.to_bits(),
            eb.worst_acc.to_bits(),
            "{label}: worst_acc @ {}",
            ea.round
        );
        assert_eq!(
            ea.avg_loss.to_bits(),
            eb.avg_loss.to_bits(),
            "{label}: avg_loss @ {}",
            ea.round
        );
    }
    assert_eq!(a.1.len(), b.1.len(), "{label}: node count");
    for (i, (pa, pb)) in a.1.iter().zip(&b.1).enumerate() {
        let ba: Vec<u32> = pa.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = pb.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ba, bb, "{label}: params of honest node {i}");
    }
}

#[test]
fn same_config_twice_is_bit_identical() {
    let cfg = base_cfg();
    let a = run_collect(&cfg);
    let b = run_collect(&cfg);
    assert_bit_identical("repeat run", &a, &b);
}

#[test]
fn different_seed_actually_changes_the_run() {
    // guards against the comparison being vacuous
    let cfg = base_cfg();
    let a = run_collect(&cfg);
    let mut cfg2 = base_cfg();
    cfg2.seed = cfg.seed + 1;
    let b = run_collect(&cfg2);
    assert_ne!(a.0.train_loss, b.0.train_loss);
}

#[test]
fn thread_count_is_invisible_in_the_results() {
    for attack in [AttackKind::Alie, AttackKind::SignFlip, AttackKind::Dos] {
        let mut serial = base_cfg();
        serial.attack = attack;
        serial.threads = 1;
        let reference = run_collect(&serial);
        for threads in [2usize, 4, 7] {
            let mut cfg = serial.clone();
            cfg.threads = threads;
            let got = run_collect(&cfg);
            assert_bit_identical(
                &format!("{attack:?} threads={threads} vs serial"),
                &reference,
                &got,
            );
        }
    }
}

#[test]
fn shards_times_threads_grid_is_invisible_in_the_results() {
    // the tentpole guarantee: partitioning the honest nodes into shards
    // changes nothing, for any worker count layered on top
    let mut reference_cfg = base_cfg();
    reference_cfg.shards = 1;
    reference_cfg.threads = 1;
    let reference = run_collect(&reference_cfg);
    for shards in [1usize, 2, 3, 5] {
        for threads in [1usize, 4] {
            if shards == 1 && threads == 1 {
                continue;
            }
            let mut cfg = base_cfg();
            cfg.shards = shards;
            cfg.threads = threads;
            let got = run_collect(&cfg);
            assert_bit_identical(
                &format!("epidemic shards={shards} threads={threads} vs serial"),
                &reference,
                &got,
            );
        }
    }
}

#[test]
fn shard_grid_holds_under_every_attack() {
    for attack in [
        AttackKind::SignFlip,
        AttackKind::Foe,
        AttackKind::Dissensus,
        AttackKind::Dos,
    ] {
        let mut serial = base_cfg();
        serial.attack = attack;
        serial.shards = 1;
        serial.threads = 1;
        let reference = run_collect(&serial);
        let mut cfg = serial.clone();
        cfg.shards = 5;
        cfg.threads = 4;
        assert_bit_identical(
            &format!("{attack:?} shards=5 threads=4 vs serial"),
            &reference,
            &run_collect(&cfg),
        );
    }
}

#[test]
fn push_topology_shard_grid_is_invariant() {
    use rpel::config::Topology;
    let mut serial = base_cfg();
    serial.topology = Topology::EpidemicPush { s: 6 };
    serial.attack = AttackKind::SignFlip;
    serial.shards = 1;
    serial.threads = 1;
    let reference = run_collect(&serial);
    for (shards, threads) in [(2usize, 1usize), (2, 4), (5, 1), (5, 4)] {
        let mut cfg = serial.clone();
        cfg.shards = shards;
        cfg.threads = threads;
        assert_bit_identical(
            &format!("push shards={shards} threads={threads} vs serial"),
            &reference,
            &run_collect(&cfg),
        );
    }
}

#[test]
fn fixed_graph_shard_grid_is_invariant() {
    let mut serial = base_cfg();
    serial.topology = rpel::config::Topology::FixedGraph { edges: 24 };
    serial.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
    serial.shards = 1;
    serial.threads = 1;
    let reference = run_collect(&serial);
    for (shards, threads) in [(2usize, 1usize), (2, 4), (5, 1), (5, 4)] {
        let mut cfg = serial.clone();
        cfg.shards = shards;
        cfg.threads = threads;
        assert_bit_identical(
            &format!("graph shards={shards} threads={threads} vs serial"),
            &reference,
            &run_collect(&cfg),
        );
    }
}

/// Point the trainer's worker spawner at the cargo-built `rpel` binary
/// (test binaries live in `deps/`, where the default resolution may not
/// find it). Uses the library's `OnceLock` hook rather than
/// `std::env::set_var`, which would race with concurrent spawns.
fn enable_worker_bin() {
    rpel::coordinator::proc::set_worker_bin(env!("CARGO_BIN_EXE_rpel"));
}

#[test]
fn multi_process_engine_is_bit_identical_on_epidemic() {
    // the tentpole guarantee: shipping the RoundDigest as a wire payload
    // changes nothing — `--procs 2` (and 3) reproduce the in-process
    // engine bit for bit, ALIE digest and all
    enable_worker_bin();
    let reference = run_collect(&base_cfg());
    for procs in [2usize, 3] {
        let mut cfg = base_cfg();
        cfg.procs = procs;
        cfg.threads = 2;
        assert_bit_identical(
            &format!("epidemic procs={procs} vs in-process"),
            &reference,
            &run_collect(&cfg),
        );
    }
}

#[test]
fn socket_transport_is_bit_identical_on_epidemic() {
    // the PR-4 tentpole guarantee: replacing the O(h·d) table broadcast
    // with worker-served pulls over sockets changes nothing — the routing
    // table dictates the same receive sets in the same order, and rows
    // travel as the same IEEE bit patterns, peer-to-peer
    use rpel::config::TransportKind;
    enable_worker_bin();
    let reference = run_collect(&base_cfg());
    for procs in [2usize, 3] {
        let mut cfg = base_cfg();
        cfg.procs = procs;
        cfg.threads = 2;
        cfg.transport = TransportKind::Socket;
        assert_bit_identical(
            &format!("epidemic socket procs={procs} vs in-process"),
            &reference,
            &run_collect(&cfg),
        );
    }
}

#[test]
fn socket_transport_is_bit_identical_on_push() {
    use rpel::config::{Topology, TransportKind};
    enable_worker_bin();
    let mut serial = base_cfg();
    serial.topology = Topology::EpidemicPush { s: 6 };
    serial.attack = AttackKind::SignFlip;
    let reference = run_collect(&serial);
    let mut cfg = serial.clone();
    cfg.procs = 2;
    cfg.transport = TransportKind::Socket;
    assert_bit_identical(
        "push socket procs=2 vs in-process",
        &reference,
        &run_collect(&cfg),
    );
}

#[test]
fn socket_transport_matches_under_dos_withholding() {
    use rpel::config::TransportKind;
    enable_worker_bin();
    let mut serial = base_cfg();
    serial.attack = AttackKind::Dos;
    let reference = run_collect(&serial);
    let mut cfg = serial.clone();
    cfg.procs = 3;
    cfg.transport = TransportKind::Socket;
    assert_bit_identical(
        "dos socket procs=3 vs in-process",
        &reference,
        &run_collect(&cfg),
    );
}

#[test]
fn socket_transport_is_bit_identical_on_fixed_graph() {
    use rpel::config::TransportKind;
    enable_worker_bin();
    let mut serial = base_cfg();
    serial.topology = rpel::config::Topology::FixedGraph { edges: 24 };
    serial.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
    let reference = run_collect(&serial);
    let mut cfg = serial.clone();
    cfg.procs = 2;
    cfg.transport = TransportKind::Socket;
    assert_bit_identical(
        "graph socket procs=2 vs in-process",
        &reference,
        &run_collect(&cfg),
    );
}

#[test]
fn socket_transport_tcp_is_bit_identical() {
    // the same listener code with TCP loopback streams — what a future
    // multi-host deployment rides — must also be bit-invisible
    use rpel::config::TransportKind;
    enable_worker_bin();
    let reference = run_collect(&base_cfg());
    let mut cfg = base_cfg();
    cfg.procs = 2;
    cfg.transport = TransportKind::Tcp;
    assert_bit_identical("epidemic tcp procs=2 vs in-process", &reference, &run_collect(&cfg));
}

#[test]
fn multi_process_engine_is_bit_identical_on_push() {
    use rpel::config::Topology;
    enable_worker_bin();
    let mut serial = base_cfg();
    serial.topology = Topology::EpidemicPush { s: 6 };
    serial.attack = AttackKind::SignFlip;
    let reference = run_collect(&serial);
    let mut cfg = serial.clone();
    cfg.procs = 2;
    assert_bit_identical("push procs=2 vs in-process", &reference, &run_collect(&cfg));
}

#[test]
fn multi_process_engine_is_bit_identical_on_fixed_graph() {
    enable_worker_bin();
    let mut serial = base_cfg();
    serial.topology = rpel::config::Topology::FixedGraph { edges: 24 };
    serial.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
    let reference = run_collect(&serial);
    let mut cfg = serial.clone();
    cfg.procs = 2;
    assert_bit_identical(
        "graph procs=2 vs in-process",
        &reference,
        &run_collect(&cfg),
    );
}

#[test]
fn multi_process_engine_matches_under_dos_withholding() {
    // DoS is where the delivered-message ledger diverges from the
    // nominal budget; the cross-process ledger must agree exactly
    enable_worker_bin();
    let mut serial = base_cfg();
    serial.attack = AttackKind::Dos;
    let reference = run_collect(&serial);
    let mut cfg = serial.clone();
    cfg.procs = 3;
    assert_bit_identical("dos procs=3 vs in-process", &reference, &run_collect(&cfg));
}

/// Like `run_collect`, but reads final models through the
/// backend-agnostic `committed_params` accessor, which works for both
/// the dense tables and the virtual-node delta-log store (where
/// `params_of` rows are intentionally empty).
fn run_collect_committed(cfg: &rpel::config::ExperimentConfig) -> (History, Vec<Vec<f32>>) {
    let mut t = Trainer::from_config(cfg).unwrap();
    let hist = t.run().unwrap();
    let params: Vec<Vec<f32>> = (0..t.honest_count())
        .map(|i| t.committed_params(i))
        .collect();
    (hist, params)
}

#[test]
fn virtual_engine_is_bit_identical_at_full_participation() {
    // the PR-7 tentpole guarantee: storing committed state as
    // (seed, delta log) and materializing lazily changes nothing —
    // at participation=1.0 the virtual backend replays the dense
    // engine bit for bit, across thread counts
    let reference = run_collect_committed(&base_cfg());
    for threads in [1usize, 4] {
        let mut cfg = base_cfg();
        cfg.virtual_nodes = true;
        cfg.threads = threads;
        assert_bit_identical(
            &format!("virtual threads={threads} vs dense"),
            &reference,
            &run_collect_committed(&cfg),
        );
    }
}

#[test]
fn partial_participation_is_invariant_across_the_grid() {
    // the PARTICIPATE coin is keyed on (seed, round, global node id),
    // so the active set — and everything downstream of it — must be
    // identical however the honest nodes are spread over shards,
    // threads, and worker processes
    use rpel::config::TransportKind;
    enable_worker_bin();
    let mut serial = base_cfg();
    serial.participation = 0.6;
    serial.shards = 1;
    serial.threads = 1;
    let reference = run_collect(&serial);
    for (shards, threads) in [(2usize, 4usize), (5, 4)] {
        let mut cfg = serial.clone();
        cfg.shards = shards;
        cfg.threads = threads;
        assert_bit_identical(
            &format!("p=0.6 shards={shards} threads={threads} vs serial"),
            &reference,
            &run_collect(&cfg),
        );
    }
    for transport in [TransportKind::Pipe, TransportKind::Socket] {
        let mut cfg = serial.clone();
        cfg.procs = 2;
        cfg.threads = 2;
        cfg.transport = transport;
        assert_bit_identical(
            &format!("p=0.6 {transport:?} procs=2 vs serial"),
            &reference,
            &run_collect(&cfg),
        );
    }
}

#[test]
fn virtual_engine_matches_dense_under_partial_participation() {
    // sparse activation end to end: the lazily-materialized active set
    // must step, serve, and commit exactly as the dense engine's frozen
    // inactive rows dictate
    let mut dense = base_cfg();
    dense.participation = 0.6;
    let reference = run_collect_committed(&dense);
    let mut cfg = dense.clone();
    cfg.virtual_nodes = true;
    cfg.threads = 4;
    assert_bit_identical(
        "virtual p=0.6 vs dense",
        &reference,
        &run_collect_committed(&cfg),
    );
}

#[test]
fn compression_actually_changes_the_trajectory() {
    // guards the grid comparisons below against being vacuous: if the
    // publish-point transform were silently skipped everywhere, every
    // compressed run would trivially equal the uncompressed one
    use rpel::wire::codec::Compression;
    let none = run_collect(&base_cfg());
    let mut cfg = base_cfg();
    cfg.compression = Compression::Q8;
    let q8 = run_collect(&cfg);
    assert_ne!(
        none.0.train_loss, q8.0.train_loss,
        "q8 quantization must be visible in the trajectory"
    );
}

#[test]
fn fixed_compression_is_bit_identical_across_the_grid() {
    // the wire-diet tentpole guarantee: decode is part of the protocol —
    // every consumer aggregates the decoded bits — so a fixed
    // compression level is ONE deterministic trajectory however the
    // honest nodes are spread over shards, threads, worker processes,
    // and transports. Compression is a modeled accuracy knob, not FP
    // noise.
    use rpel::config::TransportKind;
    use rpel::wire::codec::Compression;
    enable_worker_bin();
    for comp in [Compression::F16, Compression::Q8] {
        let mut serial = base_cfg();
        serial.compression = comp;
        serial.shards = 1;
        serial.threads = 1;
        let reference = run_collect(&serial);

        // in-process shard × thread grid
        let mut cfg = serial.clone();
        cfg.shards = 5;
        cfg.threads = 4;
        assert_bit_identical(
            &format!("{} shards=5 threads=4 vs serial", comp.name()),
            &reference,
            &run_collect(&cfg),
        );

        // multi-process grid over every transport
        for (transport, procs) in [
            (TransportKind::Pipe, 2usize),
            (TransportKind::Socket, 2),
            (TransportKind::Tcp, 2),
        ] {
            let mut cfg = serial.clone();
            cfg.procs = procs;
            cfg.threads = 2;
            cfg.transport = transport;
            let got = run_collect(&cfg);
            assert_bit_identical(
                &format!("{} {transport:?} procs={procs} vs serial", comp.name()),
                &reference,
                &got,
            );
            // the codec ledger must show the diet (and the exact f16
            // halving): raw counts 4 bytes/coord, f16 exactly 2, q8
            // strictly fewer than raw
            let raw: u64 = got.0.wire_raw_bytes_per_round.iter().sum();
            let enc: u64 = got.0.wire_encoded_bytes_per_round.iter().sum();
            assert!(raw > 0, "{}: raw ledger must be live", comp.name());
            match comp {
                Compression::F16 => assert_eq!(enc * 2, raw),
                Compression::Q8 => assert!(enc < raw),
                Compression::None => unreachable!(),
            }
        }
    }
}

#[test]
fn compression_grid_holds_under_partial_participation() {
    // participation gates which rows move, not how they encode: the
    // active-set coin is keyed on (seed, round, id), so q8 at p = 0.6
    // must stay one trajectory across the engine layouts too
    use rpel::config::TransportKind;
    use rpel::wire::codec::Compression;
    enable_worker_bin();
    let mut serial = base_cfg();
    serial.compression = Compression::Q8;
    serial.participation = 0.6;
    serial.shards = 1;
    serial.threads = 1;
    let reference = run_collect(&serial);
    let mut cfg = serial.clone();
    cfg.procs = 2;
    cfg.threads = 2;
    cfg.transport = TransportKind::Socket;
    assert_bit_identical(
        "q8 p=0.6 socket procs=2 vs serial",
        &reference,
        &run_collect(&cfg),
    );
}

#[test]
fn push_topology_is_thread_invariant_too() {
    use rpel::config::Topology;
    let mut serial = base_cfg();
    serial.topology = Topology::EpidemicPush { s: 6 };
    serial.attack = AttackKind::SignFlip;
    serial.threads = 1;
    let reference = run_collect(&serial);
    let mut par = serial.clone();
    par.threads = 4;
    assert_bit_identical("push threads=4 vs serial", &reference, &run_collect(&par));
}

#[test]
fn fixed_graph_topology_is_thread_invariant_too() {
    let mut serial = base_cfg();
    serial.topology = rpel::config::Topology::FixedGraph { edges: 24 };
    serial.rule = RuleChoice::Gossip(GossipRuleKind::CsPlus);
    serial.threads = 1;
    let reference = run_collect(&serial);
    let mut par = serial.clone();
    par.threads = 4;
    assert_bit_identical("graph threads=4 vs serial", &reference, &run_collect(&par));
}
