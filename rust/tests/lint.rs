//! Engine-grade tests for `rpel::analysis` (the `rpel lint` pass).
//!
//! Every shipped rule gets three fixtures: one that provably **fires**,
//! one that is provably **clean**, and one silenced by its **exemption
//! marker** — plus scope checks (out-of-scope paths never fire), lexer
//! false-positive checks (lint keywords inside strings/comments are
//! invisible), a whole-tree lint-clean assertion over the real source,
//! and an end-to-end CLI check (`rpel lint` exits 0 on the shipped tree,
//! nonzero — naming file, line, and rule id — on an injected violation).

// Test/bench code may time things, read the environment, and build
// scratch hash tables (clippy.toml's disallowed lists guard src only;
// the rpel-lint pass likewise skips test code).
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use rpel::analysis::{default_rules, lint_source, lint_tree, report, Finding};
use std::path::Path;

fn findings(rel_path: &str, src: &str) -> Vec<Finding> {
    lint_source(rel_path, src, &default_rules())
}

/// Assert `src` at `rel_path` produces exactly one finding for `rule`,
/// and that appending ` // lint: <rule>-exempt` to its line silences it.
fn fires_and_exempts(rel_path: &str, src: &str, rule: &str) {
    let found = findings(rel_path, src);
    assert_eq!(
        found.len(),
        1,
        "{rule} fixture at {rel_path} should fire exactly once: {found:?}"
    );
    assert_eq!(found[0].rule, rule);
    assert_eq!(found[0].file, rel_path);
    assert!(found[0].line >= 1);

    // same-line marker
    let line = found[0].line as usize;
    let mut lines: Vec<String> = src.lines().map(String::from).collect();
    lines[line - 1].push_str(&format!(" // lint: {rule}-exempt (fixture)"));
    let silenced = findings(rel_path, &lines.join("\n"));
    assert!(
        silenced.iter().all(|f| f.rule != rule),
        "same-line marker must silence {rule}: {silenced:?}"
    );

    // preceding-line marker
    let mut lines: Vec<String> = src.lines().map(String::from).collect();
    lines.insert(line - 1, format!("// lint: {rule}-exempt (fixture)"));
    let silenced = findings(rel_path, &lines.join("\n"));
    assert!(
        silenced.iter().all(|f| f.rule != rule),
        "preceding-line marker must silence {rule}: {silenced:?}"
    );

    // a marker for a *different* rule must NOT silence it
    let mut lines: Vec<String> = src.lines().map(String::from).collect();
    lines[line - 1].push_str(" // lint: some-other-exempt");
    assert_eq!(
        findings(rel_path, &lines.join("\n")).len(),
        1,
        "foreign marker must not silence {rule}"
    );
}

// ---------------------------------------------------------------------------
// rule 1: wall-clock
// ---------------------------------------------------------------------------

#[test]
fn wall_clock_fires_exempts_and_scopes() {
    let bad = "fn f() { let t = std::time::Instant::now(); }\n";
    fires_and_exempts("coordinator/fx.rs", bad, "wall-clock");
    fires_and_exempts(
        "sampling/fx.rs",
        "fn f() -> SystemTime { SystemTime::now() }\n",
        "wall-clock",
    );
    // clean: virtual-clock time is fine
    assert!(findings("coordinator/fx.rs", "fn f(now: u64) -> u64 { now + 1 }\n").is_empty());
    // out of scope: the bench harness may time things
    assert!(findings("benchkit.rs", bad).is_empty());
}

// ---------------------------------------------------------------------------
// rule 2: hash-order
// ---------------------------------------------------------------------------

#[test]
fn hash_order_fires_exempts_and_scopes() {
    let bad = "use std::collections::HashMap;\n";
    fires_and_exempts("aggregation/fx.rs", bad, "hash-order");
    fires_and_exempts(
        "coordinator/fx.rs",
        "fn f(s: std::collections::HashSet<u32>) {}\n",
        "hash-order",
    );
    // clean: ordered collections
    assert!(findings(
        "aggregation/fx.rs",
        "use std::collections::{BTreeMap, BTreeSet};\n"
    )
    .is_empty());
    assert!(findings("util/fx.rs", bad).is_empty(), "util/ out of scope");
}

// ---------------------------------------------------------------------------
// rule 3: ambient-rng
// ---------------------------------------------------------------------------

#[test]
fn ambient_rng_fires_exempts_and_scopes() {
    fires_and_exempts(
        "wire/fx.rs",
        "fn f() -> String { std::env::var(\"X\").unwrap_or_default() }\n",
        "ambient-rng",
    );
    fires_and_exempts(
        "coordinator/fx.rs",
        "fn f() -> u32 { std::process::id() }\n",
        "ambient-rng",
    );
    fires_and_exempts("sampling/fx.rs", "fn f() { let r = thread_rng(); }\n", "ambient-rng");
    // clean: counter-keyed streams
    assert!(findings(
        "sampling/fx.rs",
        "fn f(seed: u64) { let r = Rng::stream(seed, 0, 0, 0); }\n"
    )
    .is_empty());
    // `env::args` is CLI input, not ambient state
    assert!(findings("coordinator/fx.rs", "fn f() { let a = std::env::args(); }\n").is_empty());
    assert!(
        findings("util/rng.rs", "fn f() { let r = thread_rng(); }\n").is_empty(),
        "util/rng.rs is the sanctioned randomness home"
    );
}

// ---------------------------------------------------------------------------
// rule 4: panic-path
// ---------------------------------------------------------------------------

#[test]
fn panic_path_fires_exempts_and_scopes() {
    fires_and_exempts(
        "wire/fx.rs",
        "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n",
        "panic-path",
    );
    fires_and_exempts(
        "coordinator/proc.rs",
        "fn f(x: Option<u32>) -> u32 { x.expect(\"x\") }\n",
        "panic-path",
    );
    fires_and_exempts("coordinator/peer.rs", "fn f() { panic!(\"boom\"); }\n", "panic-path");
    // clean: named-error convention, and unwrap_or* are not unwrap
    let clean = "fn f(x: Option<u32>) -> Result<u32> {\n\
                 \x20   x.context(\"missing x\")\n}\n\
                 fn g(x: Option<u32>) -> u32 { x.unwrap_or_default() }\n";
    assert!(findings("wire/fx.rs", clean).is_empty());
    // coordinator/mod.rs is NOT on the panic-path scope (only proc/peer)
    assert!(findings("coordinator/mod.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n")
        .is_empty());
}

// ---------------------------------------------------------------------------
// rule 5: unchecked-alloc
// ---------------------------------------------------------------------------

#[test]
fn unchecked_alloc_fires_exempts_and_scopes() {
    fires_and_exempts(
        "wire/fx.rs",
        "fn f(n: usize) -> Vec<u8> { Vec::with_capacity(n * 4) }\n",
        "unchecked-alloc",
    );
    fires_and_exempts(
        "wire/fx.rs",
        "fn f(n: usize, d: usize) -> Vec<u8> { vec![0u8; n + d] }\n",
        "unchecked-alloc",
    );
    // clean: checked math guards the size, or no arithmetic at all
    assert!(findings(
        "wire/fx.rs",
        "fn f(n: usize) -> Result<Vec<u8>> {\n\
         \x20   let sz = n.checked_mul(4).context(\"frame too large\")?;\n\
         \x20   Ok(Vec::with_capacity(sz))\n}\n"
    )
    .is_empty());
    assert!(findings("wire/fx.rs", "fn f(n: usize) -> Vec<u8> { Vec::with_capacity(n) }\n")
        .is_empty());
    // aggregation may size scratch from trusted shapes
    assert!(findings(
        "aggregation/fx.rs",
        "fn f(n: usize) -> Vec<u8> { Vec::with_capacity(n * 4) }\n"
    )
    .is_empty());
}

// ---------------------------------------------------------------------------
// rule 6: f32-fold
// ---------------------------------------------------------------------------

#[test]
fn f32_fold_fires_exempts_and_scopes() {
    fires_and_exempts(
        "aggregation/fx.rs",
        "fn f(xs: &[f32]) -> f32 { xs.iter().sum::<f32>() }\n",
        "f32-fold",
    );
    fires_and_exempts(
        "coordinator/fx.rs",
        "fn f(xs: &[f32]) -> f32 { xs.iter().fold(0.0f32, |a, b| a + b) }\n",
        "f32-fold",
    );
    // clean: the documented f64-staged kernels
    assert!(findings(
        "aggregation/fx.rs",
        "fn f(xs: &[f32]) -> f64 { xs.iter().map(|x| *x as f64).sum::<f64>() }\n"
    )
    .is_empty());
    assert!(findings(
        "aggregation/fx.rs",
        "fn f(xs: &[f64]) -> f64 { xs.iter().fold(0.0f64, |a, b| a + b) }\n"
    )
    .is_empty());
    // attacks/ is out of scope (adversary math is spec'd per-attack)
    assert!(findings("attacks/fx.rs", "fn f(xs: &[f32]) -> f32 { xs.iter().sum::<f32>() }\n")
        .is_empty());
}

// ---------------------------------------------------------------------------
// rule 7: global-state
// ---------------------------------------------------------------------------

#[test]
fn global_state_fires_exempts_and_scopes() {
    fires_and_exempts("metrics/fx.rs", "static mut COUNTER: u64 = 0;\n", "global-state");
    fires_and_exempts(
        "util/fx.rs",
        "static EVALS: AtomicU64 = AtomicU64::new(0);\n",
        "global-state",
    );
    // clean: immutable statics and thread-local scratch
    assert!(findings("util/fx.rs", "static TABLE: [u8; 4] = [1, 2, 3, 4];\n").is_empty());
    assert!(findings(
        "util/fx.rs",
        "thread_local! {\n    static SCRATCH: RefCell<Vec<f32>> = RefCell::new(Vec::new());\n}\n"
    )
    .is_empty());
    // `'static` lifetimes are not `static` items
    assert!(findings("util/fx.rs", "fn f(s: &'static str) -> &'static str { s }\n").is_empty());
    // the sanctioned counter home: mod perf inside aggregation/mod.rs
    let perf = "pub mod perf {\n    static EVALS: AtomicU64 = AtomicU64::new(0);\n}\n";
    assert!(findings("aggregation/mod.rs", perf).is_empty());
    assert_eq!(findings("coordinator/mod.rs", perf).len(), 1, "perf is only exempt in aggregation");
}

// ---------------------------------------------------------------------------
// lexer: keywords in literals/comments never fire; cfg(test) is skipped
// ---------------------------------------------------------------------------

#[test]
fn lint_keywords_inside_strings_and_comments_do_not_fire() {
    let src = "\
// A comment mentioning Instant, HashMap, unwrap(), and panic! is prose.\n\
/* So is SystemTime in /* a nested */ block comment. */\n\
fn f() -> String {\n\
    let a = \"calling unwrap() would panic with SystemTime\".to_string();\n\
    let b = r#\"raw Instant \"quoted\" HashMap\"#;\n\
    let c = 'u'; // the char after 'u' is not an ident\n\
    format!(\"{a}{b}{c}\")\n\
}\n";
    // the fixture path puts every rule in scope at once
    assert!(findings("coordinator/proc.rs", src).is_empty(), "literals must be invisible");
}

#[test]
fn markers_inside_string_literals_do_not_exempt() {
    // The marker text lives in a *string*, not a comment: the real
    // violation on the same line must still fire.
    let src = "fn f() { let m = \"lint: wall-clock-exempt\"; let t = Instant::now(); }\n";
    let found = findings("coordinator/fx.rs", src);
    assert_eq!(found.len(), 1, "{found:?}");
    assert_eq!(found[0].rule, "wall-clock");
}

#[test]
fn cfg_test_bodies_are_out_of_scope() {
    let src = "\
fn live() {}\n\
#[cfg(test)]\n\
mod tests {\n\
    use std::collections::HashMap;\n\
    #[test]\n\
    fn t() {\n\
        let mut m = HashMap::new();\n\
        m.insert(1, std::time::Instant::now());\n\
        assert!(m.get(&1).is_some(), \"{}\", m.len());\n\
    }\n\
}\n";
    assert!(findings("coordinator/fx.rs", src).is_empty());
}

// ---------------------------------------------------------------------------
// reports
// ---------------------------------------------------------------------------

#[test]
fn findings_name_file_line_and_rule_in_both_renderings() {
    let src = "fn a() {}\nfn f() { let t = Instant::now(); }\n";
    let rep = rpel::analysis::Report {
        findings: findings("coordinator/fx.rs", src),
        files_scanned: 1,
        rules_run: default_rules().len(),
    };

    let text = report::render_text(&rep);
    assert!(text.contains("coordinator/fx.rs:2: [wall-clock]"), "{text}");
    assert!(text.contains("wall-clock-exempt"), "text points at the marker syntax: {text}");

    let json = report::render_json(&rep);
    let doc = rpel::util::json::parse(&json).expect("lint JSON parses");
    assert_eq!(doc.get("count").and_then(|c| c.as_usize()), Some(1));
    let f = &doc.get("findings").unwrap().as_arr().unwrap()[0];
    assert_eq!(f.get("file").and_then(|x| x.as_str()), Some("coordinator/fx.rs"));
    assert_eq!(f.get("line").and_then(|x| x.as_usize()), Some(2));
    assert_eq!(f.get("rule").and_then(|x| x.as_str()), Some("wall-clock"));
    assert_eq!(f.get("severity").and_then(|x| x.as_str()), Some("deny"));
}

// ---------------------------------------------------------------------------
// the shipped tree is clean — the pass is load-bearing
// ---------------------------------------------------------------------------

#[test]
fn whole_tree_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let rep = lint_tree(&root, &default_rules()).unwrap();
    assert!(
        rep.files_scanned >= 60,
        "wrong tree? scanned {} files",
        rep.files_scanned
    );
    assert!(
        rep.clean(),
        "the shipped tree must lint clean:\n{}",
        report::render_text(&rep)
    );
}

// ---------------------------------------------------------------------------
// CLI end to end: exit codes and machine output
// ---------------------------------------------------------------------------

#[test]
fn cli_exits_zero_on_clean_tree_and_nonzero_on_violation() {
    let bin = env!("CARGO_BIN_EXE_rpel");
    let repo = Path::new(env!("CARGO_MANIFEST_DIR"));

    // shipped tree: clean, exit 0
    let out = std::process::Command::new(bin)
        .args(["lint", repo.to_str().unwrap()])
        .output()
        .expect("running rpel lint");
    assert!(
        out.status.success(),
        "rpel lint must exit 0 on the shipped tree:\n{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("clean"));

    // injected violation in a scratch tree: nonzero, names file/line/rule
    let dir = std::env::temp_dir().join(format!("rpel-lint-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("coordinator")).unwrap();
    std::fs::write(
        dir.join("coordinator/bad.rs"),
        "fn f() {}\nfn g() { let t = std::time::Instant::now(); }\n",
    )
    .unwrap();
    let out = std::process::Command::new(bin)
        .args(["lint", dir.to_str().unwrap()])
        .output()
        .expect("running rpel lint on fixture");
    assert!(!out.status.success(), "violations must exit nonzero");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("coordinator/bad.rs:2: [wall-clock]"),
        "finding must name file, line, and rule id:\n{stdout}"
    );

    // --json on the same fixture parses and carries the finding
    let out = std::process::Command::new(bin)
        .args(["lint", dir.to_str().unwrap(), "--json"])
        .output()
        .expect("running rpel lint --json");
    assert!(!out.status.success());
    let doc = rpel::util::json::parse(&String::from_utf8_lossy(&out.stdout))
        .expect("lint --json emits valid JSON");
    assert_eq!(doc.get("count").and_then(|c| c.as_usize()), Some(1));

    std::fs::remove_dir_all(&dir).ok();
}
