//! Durable-checkpoint acceptance pins: resume-at-round-k must replay
//! the remaining rounds **bit-for-bit** against the straight-through
//! run on every trajectory ledger, across the (transport × procs ×
//! compression) grid plus the virtual sparse backend. Only the two
//! reporting-only columns — `wall_secs` and `checkpoint_bytes_per_round`
//! — are excluded from the equality. A corrupt checkpoint file must
//! fail resume with a named error, never a hang or a garbage run.
//!
//! The mid-run boundary state is obtained honestly: a truncated twin of
//! the config (same physics, `rounds = k`, checkpointing on) runs to
//! completion, and the full config is then grafted onto its final
//! checkpoint — by determinism the truncated run's boundary state IS
//! the straight-through run's state at round k.

use rpel::config::file::to_toml_str;
use rpel::config::{presets, Compression, ExperimentConfig, Topology, TransportKind};
use rpel::coordinator::checkpoint::{
    decode_checkpoint, encode_checkpoint, fnv1a64, read_checkpoint, write_checkpoint,
    BoundaryState, CHECKPOINT_VERSION,
};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::experiments;
use rpel::metrics::History;
use std::path::PathBuf;

fn enable_worker_bin() {
    rpel::coordinator::proc::set_worker_bin(env!("CARGO_BIN_EXE_rpel"));
}

fn base_cfg(name: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = name.into();
    cfg.n = 10;
    cfg.b = 2;
    cfg.topology = Topology::Epidemic { s: 5 };
    cfg.bhat = Some(2);
    cfg.rounds = 6;
    cfg.batch = 8;
    cfg.samples_per_node = 32;
    cfg.test_samples = 64;
    cfg.eval_every = 3;
    cfg.threads = 1;
    cfg
}

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rpel-ckpt-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The pin, for one grid point: straight-through vs checkpoint-at-3 +
/// resume. `cfg` must NOT have checkpointing on (the reference run and
/// the resumed tail both run checkpoint-free).
fn resume_equals_straight_through(cfg: &ExperimentConfig, tag: &str) {
    const CUT: usize = 3;
    let dir = scratch_dir(tag);

    let reference = Trainer::from_config(cfg)
        .unwrap_or_else(|e| panic!("{tag}: trainer builds: {e:#}"))
        .run()
        .unwrap_or_else(|e| panic!("{tag}: reference run: {e:#}"));

    // truncated twin: identical physics for rounds 0..CUT, with a
    // durable checkpoint at every boundary — the last one lands at CUT
    let mut partial = cfg.clone();
    partial.rounds = CUT;
    partial.recovery.checkpoint_dir = dir.to_str().unwrap().to_string();
    partial.recovery.checkpoint_every = 1;
    let partial_hist = Trainer::from_config(&partial).unwrap().run().unwrap();
    assert!(
        partial_hist.checkpoint_bytes_per_round.iter().all(|&b| b > 0),
        "{tag}: every boundary must have written a checkpoint"
    );

    // graft the full-run config onto the boundary state and resume
    let saved = read_checkpoint(&dir).unwrap();
    assert_eq!(saved.state.round, CUT as u64, "{tag}");
    write_checkpoint(&dir, &to_toml_str(cfg), &saved.state, &saved.hist).unwrap();
    let resumed = experiments::resume_training(dir.to_str().unwrap())
        .unwrap_or_else(|e| panic!("{tag}: resume: {e:#}"));

    let mut a = reference.clone();
    let mut b = resumed;
    a.wall_secs = 0.0;
    b.wall_secs = 0.0;
    a.checkpoint_bytes_per_round.clear();
    b.checkpoint_bytes_per_round.clear();
    assert_eq!(a, b, "{tag}: resumed trajectory must equal straight-through");
    std::fs::remove_dir_all(&dir).ok();
}

/// Golden pin of the checkpoint file envelope: magic, version, LE
/// payload length, and the FNV-1a-64 checksum over the payload — plus
/// the payload's leading bytes (the length-prefixed embedded config).
/// The encoding must also be byte-deterministic.
#[test]
fn golden_checkpoint_envelope() {
    let state = BoundaryState {
        round: 1,
        wire_ref: vec![0.5f32],
        params: vec![vec![1.0f32]],
        momentum: vec![vec![-1.0f32]],
        carried: vec![None],
        vclock: None,
    };
    let hist = History::new("g", 1);
    // encode_checkpoint embeds the config string verbatim — the envelope
    // is checkable without a parseable config
    let bytes = encode_checkpoint("x", &state, &hist);
    assert_eq!(&bytes[..8], b"RPELCKPT");
    assert_eq!(bytes[8..12], CHECKPOINT_VERSION.to_le_bytes());
    let payload = &bytes[28..];
    assert_eq!(bytes[12..20], (payload.len() as u64).to_le_bytes());
    assert_eq!(bytes[20..28], fnv1a64(payload).to_le_bytes());
    // payload leads with the len-prefixed config string, then the round
    assert_eq!(&payload[..5], &[0x01, 0x00, 0x00, 0x00, b'x']);
    assert_eq!(payload[5..13], 1u64.to_le_bytes());
    assert_eq!(bytes, encode_checkpoint("x", &state, &hist));
}

/// Forall-style round-trip over the shape grid: model width × carried
/// pattern × vclock presence, all at the embedded config's honest
/// count. Decode must reproduce every field exactly.
#[test]
fn checkpoint_roundtrips_across_shape_grid() {
    let cfg = presets::quickstart_config();
    let toml = to_toml_str(&cfg);
    let h = cfg.honest();
    for d in [1usize, 3, 8] {
        for carried_mode in 0..3 {
            for vclock_on in [false, true] {
                let state = BoundaryState {
                    round: 2,
                    wire_ref: (0..d).map(|j| j as f32 * 0.25).collect(),
                    params: (0..h).map(|i| vec![i as f32; d]).collect(),
                    momentum: (0..h).map(|i| vec![-(i as f32) * 0.5; d]).collect(),
                    carried: (0..h)
                        .map(|i| match carried_mode {
                            0 => None,
                            1 => Some(vec![7.0f32; d]),
                            _ => (i % 2 == 0).then(|| vec![i as f32 * 0.1; d]),
                        })
                        .collect(),
                    vclock: vclock_on.then(|| {
                        ((0..h as u64).collect(), (0..h as u64).map(|x| x * 2).collect())
                    }),
                };
                let mut hist = History::new("grid", 9);
                hist.train_loss = vec![0.5; 2];
                hist.peer_retries_per_round = vec![1, 0];
                let bytes = encode_checkpoint(&toml, &state, &hist);
                let back = decode_checkpoint(&bytes)
                    .unwrap_or_else(|e| panic!("d={d} mode={carried_mode}: {e:#}"));
                assert_eq!(back.state, state, "d={d} mode={carried_mode}");
                assert_eq!(back.hist, hist);
                assert_eq!(back.cfg, cfg);
            }
        }
    }
}

#[test]
fn resume_matches_in_process_none() {
    let mut cfg = base_cfg("ckpt_inproc_none");
    cfg.shards = 2;
    resume_equals_straight_through(&cfg, "inproc-none");
}

#[test]
fn resume_matches_in_process_q8() {
    let mut cfg = base_cfg("ckpt_inproc_q8");
    cfg.shards = 2;
    cfg.compression = Compression::Q8;
    resume_equals_straight_through(&cfg, "inproc-q8");
}

#[test]
fn resume_matches_pipe_procs2_none() {
    enable_worker_bin();
    let mut cfg = base_cfg("ckpt_pipe_none");
    cfg.procs = 2;
    resume_equals_straight_through(&cfg, "pipe-none");
}

#[test]
fn resume_matches_pipe_procs2_q8() {
    enable_worker_bin();
    let mut cfg = base_cfg("ckpt_pipe_q8");
    cfg.procs = 2;
    cfg.compression = Compression::Q8;
    resume_equals_straight_through(&cfg, "pipe-q8");
}

#[test]
fn resume_matches_socket_procs2_none() {
    enable_worker_bin();
    let mut cfg = base_cfg("ckpt_socket_none");
    cfg.procs = 2;
    cfg.transport = TransportKind::Socket;
    resume_equals_straight_through(&cfg, "socket-none");
}

#[test]
fn resume_matches_socket_procs2_q8() {
    enable_worker_bin();
    let mut cfg = base_cfg("ckpt_socket_q8");
    cfg.procs = 2;
    cfg.transport = TransportKind::Socket;
    cfg.compression = Compression::Q8;
    resume_equals_straight_through(&cfg, "socket-q8");
}

#[test]
fn resume_matches_virtual_backend() {
    let mut cfg = base_cfg("ckpt_virtual");
    cfg.virtual_nodes = true;
    cfg.participation = 0.8;
    resume_equals_straight_through(&cfg, "virtual");
}

/// File-level fault coverage through the real CLI entry path: a
/// flipped payload byte must fail `resume_training` with the checksum
/// named; a truncated file with the length named. Never a hang, never
/// a silently wrong run.
#[test]
fn corrupt_checkpoint_fails_resume_with_named_error() {
    let dir = scratch_dir("corrupt");
    let mut cfg = base_cfg("ckpt_corrupt");
    cfg.shards = 2;
    cfg.rounds = 2;
    cfg.recovery.checkpoint_dir = dir.to_str().unwrap().to_string();
    cfg.recovery.checkpoint_every = 1;
    Trainer::from_config(&cfg).unwrap().run().unwrap();

    let path = dir.join("checkpoint.bin");
    let clean = std::fs::read(&path).unwrap();

    let mut flipped = clean.clone();
    *flipped.last_mut().unwrap() ^= 0x01;
    std::fs::write(&path, &flipped).unwrap();
    let err = format!(
        "{:#}",
        experiments::resume_training(dir.to_str().unwrap()).unwrap_err()
    );
    assert!(err.contains("checksum mismatch"), "{err}");

    std::fs::write(&path, &clean[..clean.len() - 1]).unwrap();
    let err = format!(
        "{:#}",
        experiments::resume_training(dir.to_str().unwrap()).unwrap_err()
    );
    assert!(err.contains("does not match"), "{err}");

    std::fs::remove_dir_all(&dir).ok();
}
