//! The asynchronous round engine on its deterministic virtual clock:
//!
//! * **neutral equivalence** — `quorum = h`, `max_staleness = 0`, no
//!   churn, constant latency must reproduce the synchronous engine **bit
//!   for bit**, across the whole transport × procs × shards × threads
//!   grid (asynchrony off is not a separate code path's luck; it is the
//!   async engine collapsing to lockstep);
//! * **grid invariance** — a genuinely asynchronous config (stragglers,
//!   bounded staleness, churn) is itself bit-identical across the same
//!   grid and across repeat runs: staleness is *modeled* on counter-keyed
//!   streams, never measured off a wall clock;
//! * **ledger recomputation** — the participation, virtual-close and
//!   staleness-histogram ledgers equal an independent recomputation from
//!   the public `(seed, round, node, LATENCY/CHURN)` streams, byte-exact
//!   (the `message_accounting.rs` idiom applied to the virtual clock).

use rpel::attacks::AttackKind;
use rpel::config::{AsyncCfg, ExperimentConfig, StalePolicyKind, StragglerKind, Topology, TransportKind};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::metrics::History;
use rpel::util::rng::{stream_tag, Rng};
use rpel::util::vclock::sample_latency;

const ROUNDS: usize = 10;

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.n = 12;
    cfg.b = 2;
    cfg.topology = Topology::Epidemic { s: 6 };
    cfg.bhat = Some(2);
    cfg.attack = AttackKind::Alie;
    cfg.rounds = ROUNDS;
    cfg.batch = 8;
    cfg.samples_per_node = 48;
    cfg.test_samples = 96;
    cfg.eval_every = 5;
    cfg
}

/// A config that actually exercises stragglers, decay and churn.
fn async_cfg() -> ExperimentConfig {
    let mut cfg = base_cfg();
    cfg.asyn = AsyncCfg {
        quorum: 5,
        max_staleness: 2,
        stale_policy: StalePolicyKind::Decay,
        stale_decay: 0.5,
        straggler: StragglerKind::TwoPoint,
        slow_prob: 0.35,
        slow_latency: 4.0,
        crash_prob: 0.1,
        down_rounds: 2,
        ..AsyncCfg::default()
    };
    cfg
}

fn run_collect(cfg: &ExperimentConfig) -> (History, Vec<Vec<f32>>) {
    let mut t = Trainer::from_config(cfg).unwrap();
    let hist = t.run().unwrap();
    let params: Vec<Vec<f32>> = (0..t.honest_count())
        .map(|i| t.params_of(i).to_vec())
        .collect();
    (hist, params)
}

/// Exact equality of the training outcome: losses, evals, message
/// ledgers, final models. Wire-byte ledgers are deliberately NOT
/// compared — the async engine ships one extra `AsyncRound` frame per
/// worker per round, which is a protocol cost, not a training
/// divergence.
fn assert_bit_identical(label: &str, a: &(History, Vec<Vec<f32>>), b: &(History, Vec<Vec<f32>>)) {
    let bits64 = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits64(&a.0.train_loss),
        bits64(&b.0.train_loss),
        "{label}: train_loss"
    );
    assert_eq!(
        a.0.observed_byz_max, b.0.observed_byz_max,
        "{label}: observed_byz_max"
    );
    assert_eq!(a.0.total_messages, b.0.total_messages, "{label}: messages");
    assert_eq!(
        a.0.delivered_per_round, b.0.delivered_per_round,
        "{label}: delivered_per_round"
    );
    assert_eq!(a.0.evals.len(), b.0.evals.len(), "{label}: eval count");
    for (ea, eb) in a.0.evals.iter().zip(&b.0.evals) {
        assert_eq!(ea.round, eb.round, "{label}: eval round");
        assert_eq!(
            ea.avg_acc.to_bits(),
            eb.avg_acc.to_bits(),
            "{label}: avg_acc @ {}",
            ea.round
        );
    }
    assert_eq!(a.1.len(), b.1.len(), "{label}: node count");
    for (i, (pa, pb)) in a.1.iter().zip(&b.1).enumerate() {
        let ba: Vec<u32> = pa.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = pb.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ba, bb, "{label}: params of honest node {i}");
    }
}

/// Async-engine ledgers must also match exactly across the grid.
fn assert_ledgers_identical(label: &str, a: &History, b: &History) {
    assert_eq!(
        a.participation_per_round, b.participation_per_round,
        "{label}: participation ledger"
    );
    let bits64 = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits64(&a.virtual_close_per_round),
        bits64(&b.virtual_close_per_round),
        "{label}: virtual-close ledger"
    );
    assert_eq!(a.staleness_hist, b.staleness_hist, "{label}: staleness histogram");
}

fn enable_worker_bin() {
    rpel::coordinator::proc::set_worker_bin(env!("CARGO_BIN_EXE_rpel"));
}

/// The (transport × procs × shards × threads) grid every async property
/// must hold on. transport only matters with worker processes, so the
/// pipe/socket split rides the procs=2 points.
fn grid() -> Vec<(TransportKind, usize, usize, usize)> {
    let mut out = Vec::new();
    for &shards in &[1usize, 3] {
        for &threads in &[1usize, 4] {
            out.push((TransportKind::Pipe, 1, shards, threads));
            out.push((TransportKind::Pipe, 2, shards, threads));
            out.push((TransportKind::Socket, 2, shards, threads));
        }
    }
    out
}

#[test]
fn neutral_async_grid_reproduces_sync_bit_for_bit() {
    enable_worker_bin();
    let sync = run_collect(&base_cfg());
    assert!(
        sync.0.participation_per_round.is_empty(),
        "sync runs must not record async ledgers"
    );

    let h = base_cfg().n - base_cfg().b;
    for (transport, procs, shards, threads) in grid() {
        let mut cfg = base_cfg();
        cfg.asyn.quorum = h; // neutral: every honest node makes the cut
        cfg.transport = transport;
        cfg.procs = procs;
        cfg.shards = shards;
        cfg.threads = threads;
        let got = run_collect(&cfg);
        assert_bit_identical(
            &format!("neutral {transport:?} procs={procs} shards={shards} threads={threads}"),
            &sync,
            &got,
        );
        assert_eq!(
            got.0.participation_per_round,
            vec![h as u32; ROUNDS],
            "neutral runs participate in full every round"
        );
        assert_eq!(got.0.staleness_hist[0], (h * ROUNDS) as u64);
        assert!(got.0.staleness_hist[1..].iter().all(|&x| x == 0));
    }
}

#[test]
fn straggler_config_is_bit_identical_across_the_grid_and_repeats() {
    enable_worker_bin();
    let reference = run_collect(&async_cfg());

    // repeat run first: same process, same config, same bits
    let again = run_collect(&async_cfg());
    assert_bit_identical("async repeat run", &reference, &again);
    assert_ledgers_identical("async repeat run", &reference.0, &again.0);

    // the run must actually be asynchronous, or the grid pin is vacuous
    assert!(
        reference
            .0
            .participation_per_round
            .iter()
            .any(|&p| (p as usize) < async_cfg().n - async_cfg().b),
        "straggler config never produced a short round"
    );
    assert!(
        reference.0.staleness_hist[1..].iter().sum::<u64>() > 0,
        "straggler config never produced a stale serve"
    );

    for (transport, procs, shards, threads) in grid() {
        let mut cfg = async_cfg();
        cfg.transport = transport;
        cfg.procs = procs;
        cfg.shards = shards;
        cfg.threads = threads;
        let got = run_collect(&cfg);
        let label =
            format!("async {transport:?} procs={procs} shards={shards} threads={threads}");
        assert_bit_identical(&label, &reference, &got);
        assert_ledgers_identical(&label, &reference.0, &got.0);
    }
}

#[test]
fn different_seed_changes_the_async_run() {
    // guards against the grid comparison being vacuous
    let a = run_collect(&async_cfg());
    let mut cfg = async_cfg();
    cfg.seed += 1;
    let b = run_collect(&cfg);
    assert_ne!(a.0.train_loss, b.0.train_loss);
    let bits64 = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert!(
        a.0.participation_per_round != b.0.participation_per_round
            || a.0.staleness_hist != b.0.staleness_hist
            || bits64(&a.0.virtual_close_per_round) != bits64(&b.0.virtual_close_per_round),
        "churn/straggler schedule must be seed-derived"
    );
}

/// Independent twin of the coordinator's virtual clock, built only from
/// the config and the public counter-keyed streams: churn coins from
/// `(seed, round, node, CHURN)`, latencies via [`sample_latency`] (a
/// pure function of `(seed, round, node, LATENCY)`), the quorum close
/// and staleness aging re-derived from the documented rules.
fn recompute_ledgers(cfg: &ExperimentConfig) -> (Vec<u32>, Vec<f64>, Vec<u64>) {
    let a = &cfg.asyn;
    let h = cfg.n - cfg.b;
    let mut down_until = vec![0u64; h];
    let mut last_fresh = vec![0u64; h];
    let mut participation = Vec::with_capacity(cfg.rounds);
    let mut vclose = Vec::with_capacity(cfg.rounds);
    let mut hist = vec![0u64; a.max_staleness + 2];
    for round in 1..=cfg.rounds as u64 {
        if a.crash_prob > 0.0 {
            for i in 0..h {
                let u = Rng::stream(cfg.seed, round, i as u64, stream_tag::CHURN).f64();
                if u < a.crash_prob && round >= down_until[i] {
                    down_until[i] = round + a.down_rounds as u64;
                }
            }
        }
        let in_part = (round as usize) >= a.part_from && (round as usize) < a.part_to;
        let down: Vec<bool> = (0..h)
            .map(|i| round < down_until[i] || (in_part && i < a.part_nodes))
            .collect();
        let lat: Vec<f64> = (0..h)
            .map(|i| {
                if down[i] {
                    f64::INFINITY
                } else {
                    sample_latency(a, cfg.seed, round, i as u64)
                }
            })
            .collect();
        let mut alive: Vec<f64> = lat.iter().copied().filter(|l| l.is_finite()).collect();
        alive.sort_unstable_by(f64::total_cmp);
        let q = if a.quorum == 0 { h } else { a.quorum };
        let q_eff = q.min(alive.len());
        let mut close = if q_eff == 0 { 0.0 } else { alive[q_eff - 1] };
        if a.deadline > 0.0 {
            close = close.min(a.deadline);
        }
        let mut fresh_count = 0u32;
        let cap = a.max_staleness as u64 + 1;
        for i in 0..h {
            let st = if !down[i] && lat[i] <= close {
                last_fresh[i] = round;
                fresh_count += 1;
                0u32
            } else {
                (round - last_fresh[i]).min(cap) as u32
            };
            hist[st as usize] += 1;
        }
        participation.push(fresh_count);
        vclose.push(close);
    }
    (participation, vclose, hist)
}

#[test]
fn ledgers_match_independent_stream_recomputation() {
    for cfg in [async_cfg(), {
        // a second shape: lognormal stragglers + a partition window
        let mut c = base_cfg();
        c.asyn = AsyncCfg {
            quorum: 7,
            max_staleness: 3,
            straggler: StragglerKind::LogNormal,
            sigma: 0.6,
            part_from: 3,
            part_to: 6,
            part_nodes: 2,
            ..AsyncCfg::default()
        };
        c
    }] {
        let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let (participation, vclose, stale_hist) = recompute_ledgers(&cfg);
        assert_eq!(
            hist.participation_per_round, participation,
            "{}: participation ledger",
            cfg.asyn.straggler.name()
        );
        let bits: Vec<u64> = hist.virtual_close_per_round.iter().map(|x| x.to_bits()).collect();
        let expect_bits: Vec<u64> = vclose.iter().map(|x| x.to_bits()).collect();
        assert_eq!(
            bits, expect_bits,
            "{}: virtual-close ledger (bit-exact)",
            cfg.asyn.straggler.name()
        );
        assert_eq!(
            hist.staleness_hist, stale_hist,
            "{}: staleness histogram",
            cfg.asyn.straggler.name()
        );
        // the buckets account for every (round, node) pair exactly once
        let h = (cfg.n - cfg.b) as u64;
        assert_eq!(hist.staleness_hist.iter().sum::<u64>(), h * cfg.rounds as u64);
    }
}

#[test]
fn deadline_cap_limits_participation() {
    // a deadline below the slow latency: slow nodes can never arrive,
    // so every round's participation is exactly the fast population
    let mut cfg = async_cfg();
    cfg.asyn.crash_prob = 0.0;
    cfg.asyn.quorum = 10; // ask for everyone…
    cfg.asyn.deadline = 2.0; // …but cap the wait below slow_latency = 4
    let hist = Trainer::from_config(&cfg).unwrap().run().unwrap();
    let h = cfg.n - cfg.b;
    for (round, &p) in hist.participation_per_round.iter().enumerate() {
        let fast = (0..h)
            .filter(|&i| {
                sample_latency(&cfg.asyn, cfg.seed, round as u64 + 1, i as u64)
                    <= cfg.asyn.deadline
            })
            .count() as u32;
        assert_eq!(p, fast, "round {round}: deadline-capped participation");
        assert!(hist.virtual_close_per_round[round] <= cfg.asyn.deadline);
    }
}
