//! Regression: digest-based attack crafting must reproduce the removed
//! full-scan implementations.
//!
//! ALIE used to receive a borrow of *all* honest half-steps
//! (`AttackContext::honest_all`) and recompute the per-coordinate variance
//! for every victim — an O(h²·d) round cost. The context now carries a
//! per-round `HonestDigest` (f64 mean/std/prev-mean) instead. This test
//! pins the old full-scan behavior as an oracle (reimplemented here
//! exactly as it was: f32-accumulated mean, f64 variance around it) and
//! checks the digest path lands within 1e-5 on a fixed fixture.

use rpel::attacks::{Alie, Attack, AttackContext, HonestDigest, SignFlip};
use rpel::util::rng::Rng;

struct FixtureData {
    halves: Vec<Vec<f32>>,
    prevs: Vec<Vec<f32>>,
}

/// Deterministic honest population: h rows of dimension d, magnitudes ~1.
fn fixture(h: usize, d: usize, seed: u64) -> FixtureData {
    let mut rng = Rng::new(seed);
    let halves: Vec<Vec<f32>> = (0..h)
        .map(|_| (0..d).map(|_| rng.gaussian32(0.0, 1.0)).collect())
        .collect();
    let prevs: Vec<Vec<f32>> = halves
        .iter()
        .map(|r| r.iter().map(|x| x + 0.1 * rng.gaussian32(0.0, 1.0)).collect())
        .collect();
    FixtureData { halves, prevs }
}

/// The removed `honest_all` full-scan ALIE, verbatim: μ_j from the
/// engine's old f32-accumulated column mean, σ_j rescanned per victim in
/// f64 around that μ.
fn full_scan_alie(halves: &[&[f32]], z: f32, out: &mut [Vec<f32>]) {
    let d = halves[0].len();
    let m = halves.len() as f64;
    // old column_mean: f32 accumulate, f32 scale
    let mut mean32 = vec![0.0f32; d];
    for row in halves {
        for (acc, &x) in mean32.iter_mut().zip(row.iter()) {
            *acc += x;
        }
    }
    let inv = 1.0f32 / m as f32;
    for acc in mean32.iter_mut() {
        *acc *= inv;
    }
    for row in out.iter_mut() {
        for j in 0..d {
            let mu = mean32[j] as f64;
            let mut var = 0.0f64;
            for h in halves {
                let dlt = h[j] as f64 - mu;
                var += dlt * dlt;
            }
            let sigma = (var / m).sqrt();
            row[j] = (mu - z as f64 * sigma) as f32;
        }
    }
}

#[test]
fn digest_alie_matches_removed_full_scan_within_1e5() {
    let (h, d, n, b) = (40usize, 64usize, 45usize, 5usize);
    let fx = fixture(h, d, 7);
    let halves: Vec<&[f32]> = fx.halves.iter().map(|v| v.as_slice()).collect();
    let prevs: Vec<&[f32]> = fx.prevs.iter().map(|v| v.as_slice()).collect();
    let digest = HonestDigest::compute(&halves, &prevs);
    assert_eq!(digest.count, h);

    let z = Alie::z_max(n, b);
    let mut want = vec![vec![0.0f32; d]; b];
    full_scan_alie(&halves, z, &mut want);

    let ctx = AttackContext {
        victim_half: halves[0],
        victim_prev: prevs[0],
        honest_received: &halves[1..4],
        digest: &digest,
        n,
        b,
    };
    let mut got = vec![vec![0.0f32; d]; b];
    Alie::default().craft(&ctx, &mut got);

    for (row_got, row_want) in got.iter().zip(&want) {
        for (j, (g, w)) in row_got.iter().zip(row_want.iter()).enumerate() {
            assert!(
                (g - w).abs() < 1e-5,
                "coordinate {j}: digest={g} full-scan={w}"
            );
        }
    }
}

#[test]
fn digest_alie_is_independent_of_received_rows() {
    // omniscience comes from the digest, not from what the victim pulled:
    // the crafted envelope point must not depend on the received subset
    let fx = fixture(20, 16, 3);
    let halves: Vec<&[f32]> = fx.halves.iter().map(|v| v.as_slice()).collect();
    let prevs: Vec<&[f32]> = fx.prevs.iter().map(|v| v.as_slice()).collect();
    let digest = HonestDigest::compute(&halves, &prevs);
    let craft = |received: &[&[f32]]| {
        let ctx = AttackContext {
            victim_half: halves[0],
            victim_prev: prevs[0],
            honest_received: received,
            digest: &digest,
            n: 23,
            b: 3,
        };
        let mut out = vec![vec![0.0f32; 16]];
        Alie::default().craft(&ctx, &mut out);
        out.remove(0)
    };
    assert_eq!(craft(&halves[1..3]), craft(&halves[5..11]));
}

#[test]
fn digest_sign_flip_matches_mean_formula_within_1e5() {
    // SF's formula is a pure function of the two means; the digest path
    // must agree with computing it from f32 column means directly
    let fx = fixture(30, 32, 11);
    let halves: Vec<&[f32]> = fx.halves.iter().map(|v| v.as_slice()).collect();
    let prevs: Vec<&[f32]> = fx.prevs.iter().map(|v| v.as_slice()).collect();
    let digest = HonestDigest::compute(&halves, &prevs);
    let ctx = AttackContext {
        victim_half: halves[0],
        victim_prev: prevs[0],
        honest_received: &halves[1..5],
        digest: &digest,
        n: 33,
        b: 3,
    };
    let mut got = vec![vec![0.0f32; 32]];
    SignFlip { gamma: 4.0 }.craft(&ctx, &mut got);
    for j in 0..32 {
        let mu: f64 = halves.iter().map(|r| r[j] as f64).sum::<f64>() / 30.0;
        let pm: f64 = prevs.iter().map(|r| r[j] as f64).sum::<f64>() / 30.0;
        let want = (pm - 4.0 * (mu - pm)) as f32;
        assert!((got[0][j] - want).abs() < 1e-5, "j={j}");
    }
}
