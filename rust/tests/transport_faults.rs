//! Fault-injection suite for the round protocol, over both transports.
//!
//! Every injected fault — short writes, split reads, mid-frame EOF,
//! delayed replies, stale-round replies, a peer dying with a pull in
//! flight — must surface as an **actionable error naming the worker and
//! the round** (or change nothing at all, for delays): never a hang,
//! never silent corruption. Faults are keyed off the deterministic
//! counter RNG ([`rpel::testkit::chaos`]), so a failing case reproduces
//! from its seed.

// Test/bench code may time things, read the environment, and build
// scratch hash tables (clippy.toml's disallowed lists guard src only;
// the rpel-lint pass likewise skips test code).
#![allow(clippy::disallowed_methods, clippy::disallowed_types)]

use rpel::config::{ExperimentConfig, Topology, TransportKind};
use rpel::coordinator::peer::{PeerClient, RowServer};
use rpel::coordinator::proc::run_worker;
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::testkit::chaos::{ChaosPlan, ChaosStream};
use rpel::wire;
use rpel::wire::codec::RowCodec;
use rpel::wire::proto::{self, PeerEntry, PeerMsg};
use rpel::wire::transport::{
    Listener, RetryPolicy, SockAddr, SocketStream, SocketTransport, Transport,
};
use std::io::Write;
use std::time::Duration;

fn enable_worker_bin() {
    rpel::coordinator::proc::set_worker_bin(env!("CARGO_BIN_EXE_rpel"));
}

fn chaos_cfg(name: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = name.into();
    cfg.n = 10;
    cfg.b = 2;
    cfg.topology = Topology::Epidemic { s: 5 };
    cfg.bhat = Some(2);
    cfg.rounds = 6;
    cfg.batch = 8;
    cfg.samples_per_node = 32;
    cfg.test_samples = 64;
    cfg.eval_every = 100;
    cfg.procs = 2;
    cfg.threads = 1;
    cfg
}

fn tcp_pair() -> (SocketStream, SocketStream) {
    let listener = Listener::bind(&SockAddr::Tcp("127.0.0.1:0".into())).unwrap();
    let addr = listener.local_addr().unwrap();
    let client = std::thread::spawn(move || SocketStream::connect(&addr).unwrap());
    let server = listener.accept().unwrap();
    server.set_nonblocking(false).unwrap();
    (server, client.join().unwrap())
}

// ---------------------------------------------------------------------------
// Byte-level faults: the framed codec itself
// ---------------------------------------------------------------------------

#[test]
fn protocol_frames_survive_split_reads_and_short_writes_on_pipes() {
    let original = proto::encode_init("task = \"tiny\"", 1, 2, &proto::WireResume::default());
    let mut stream_bytes = Vec::new();
    {
        let mut chaotic = ChaosStream::new(&mut stream_bytes, 11).short_writes();
        wire::write_frame(&mut chaotic, &original).unwrap();
        chaotic.flush().unwrap();
    }
    let mut chaotic = ChaosStream::new(std::io::Cursor::new(stream_bytes), 12).split_reads();
    let frame = wire::read_frame(&mut chaotic).unwrap();
    assert_eq!(frame, original, "bytes must be identical, not just parseable");
    proto::decode_to_worker(&frame).unwrap();
}

#[test]
fn protocol_frames_survive_split_reads_on_sockets() {
    let (server, mut client) = tcp_pair();
    let original = proto::encode_pull_reply(9, &[vec![1.0f32, -2.0], vec![0.5, 4.0]]);
    let payload = original.clone();
    let writer = std::thread::spawn(move || {
        wire::write_frame(&mut client, &payload).unwrap();
        client.flush().unwrap();
    });
    let mut chaotic = ChaosStream::new(server, 13).split_reads();
    let frame = wire::read_frame(&mut chaotic).unwrap();
    writer.join().unwrap();
    assert_eq!(frame, original);
}

#[test]
fn peer_dying_mid_frame_on_socket_is_an_error_not_a_hang() {
    let (server, mut client) = tcp_pair();
    // header promises 1000 bytes; the peer dies after 4 of them
    let writer = std::thread::spawn(move || {
        client.write_all(&1000u32.to_le_bytes()).unwrap();
        client.write_all(&[0xAB; 4]).unwrap();
        client.flush().unwrap();
        drop(client);
    });
    let mut t = SocketTransport::from_stream(server).unwrap();
    let err = t.recv().unwrap_err().to_string();
    writer.join().unwrap();
    assert!(err.contains("mid-frame"), "{err}");
}

// ---------------------------------------------------------------------------
// Worker-loop faults (pipe path, in-process via scripted streams)
// ---------------------------------------------------------------------------

#[test]
fn worker_loop_surfaces_mid_frame_eof_after_handshake() {
    // script: a valid Init frame, then a frame cut off mid-body
    let mut input = Vec::new();
    wire::write_frame(
        &mut input,
        &proto::encode_init("task = \"tiny\"", 0, 2, &proto::WireResume::default()),
    )
    .unwrap();
    input.extend_from_slice(&50u32.to_le_bytes());
    input.extend_from_slice(&[0u8; 10]); // 40 bytes short
    let mut output = Vec::new();
    let err = run_worker(std::io::Cursor::new(input), &mut output)
        .unwrap_err()
        .to_string();
    assert!(err.contains("mid-frame"), "{err}");
    // the handshake reply still made it out before the fault
    let mut out = std::io::Cursor::new(output);
    let first = wire::read_frame(&mut out).unwrap();
    assert!(matches!(
        proto::decode_from_worker(&first).unwrap(),
        proto::FromWorker::InitOk { .. }
    ));
}

#[test]
fn worker_loop_survives_chaotic_byte_stream() {
    // the same script delivered through split reads must behave
    // identically (framing is below the protocol, faults and all)
    let mut input = Vec::new();
    wire::write_frame(
        &mut input,
        &proto::encode_init("task = \"tiny\"", 0, 2, &proto::WireResume::default()),
    )
    .unwrap();
    wire::write_frame(&mut input, &proto::encode_shutdown()).unwrap();
    let mut output = Vec::new();
    run_worker(
        ChaosStream::new(std::io::Cursor::new(input), 21).split_reads(),
        &mut output,
    )
    .expect("orderly shutdown through a chaotic stream");
}

// ---------------------------------------------------------------------------
// Transport-level faults against real worker processes, both transports
// ---------------------------------------------------------------------------

fn stale_replay_names_worker_and_round(transport: TransportKind) {
    enable_worker_bin();
    let mut cfg = chaos_cfg(&format!("chaos_stale_{}", transport.name()));
    cfg.transport = transport;
    let mut t = Trainer::from_config(&cfg).unwrap();
    // replace the 3rd post-handshake reply (round 1's Snapshot) with a
    // byte-exact replay of the 1st (round 0's Snapshot) — exactly what a
    // reply stranded by an aborted round looks like
    assert!(t.chaos_shard_transport(
        1,
        ChaosPlan {
            replay: Some((2, 0)),
            ..Default::default()
        }
    ));
    assert!(
        !t.chaos_shard_transport(99, ChaosPlan::default()),
        "out-of-range shard index must report false"
    );
    let mut failure = None;
    for round in 0..cfg.rounds {
        if let Err(e) = t.round(round) {
            failure = Some(format!("{e:#}"));
            break;
        }
    }
    let msg = failure.expect("a stale reply must fail the round");
    assert!(msg.contains("stale Snapshot"), "{msg}");
    assert!(msg.contains("shard worker 1"), "{msg}");
    assert!(msg.contains("round 0"), "should name the stale round: {msg}");
}

#[test]
fn stale_replay_errors_on_pipe_transport() {
    stale_replay_names_worker_and_round(TransportKind::Pipe);
}

#[test]
fn stale_replay_errors_on_socket_transport() {
    stale_replay_names_worker_and_round(TransportKind::Socket);
}

fn cut_stream_names_worker(transport: TransportKind) {
    enable_worker_bin();
    let mut cfg = chaos_cfg(&format!("chaos_cut_{}", transport.name()));
    cfg.transport = transport;
    let mut t = Trainer::from_config(&cfg).unwrap();
    assert!(t.chaos_shard_transport(
        0,
        ChaosPlan {
            cut_at: Some(1),
            ..Default::default()
        }
    ));
    let msg = format!("{:#}", t.round(0).unwrap_err());
    assert!(msg.contains("shard worker 0"), "{msg}");
    assert!(msg.contains("awaiting reply"), "{msg}");
    drop(t); // teardown with a half-dead round must not deadlock
}

#[test]
fn cut_stream_errors_on_pipe_transport() {
    cut_stream_names_worker(TransportKind::Pipe);
}

#[test]
fn cut_stream_errors_on_socket_transport() {
    cut_stream_names_worker(TransportKind::Socket);
}

#[test]
fn delayed_replies_change_nothing() {
    enable_worker_bin();
    let mut cfg = chaos_cfg("chaos_delay");
    cfg.rounds = 3;
    cfg.transport = TransportKind::Socket;
    let reference = Trainer::from_config(&cfg).unwrap().run().unwrap();

    let mut t = Trainer::from_config(&cfg).unwrap();
    assert!(t.chaos_shard_transport(
        0,
        ChaosPlan {
            recv_delay: Some(Duration::from_millis(10)),
            ..Default::default()
        }
    ));
    let delayed = t.run().unwrap();
    assert_eq!(reference.train_loss, delayed.train_loss);
    assert_eq!(reference.observed_byz_max, delayed.observed_byz_max);
}

// ---------------------------------------------------------------------------
// Peer pull serving: a dying or misbehaving peer, seen from the puller
// ---------------------------------------------------------------------------

/// A fake peer listener driven by a closure; returns the bound address.
fn fake_peer<F>(script: F) -> SockAddr
where
    F: FnOnce(SocketStream) + Send + 'static,
{
    let listener = Listener::bind(&SockAddr::Tcp("127.0.0.1:0".into())).unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let stream = listener.accept().unwrap();
        stream.set_nonblocking(false).unwrap();
        script(stream);
    });
    addr
}

fn two_worker_book(fake: &SockAddr) -> Vec<PeerEntry> {
    vec![
        PeerEntry {
            start: 0,
            len: 5,
            addr: "tcp:127.0.0.1:1".into(), // never dialed (own range)
        },
        PeerEntry {
            start: 5,
            len: 5,
            addr: fake.to_string(),
        },
    ]
}

#[test]
fn peer_killed_mid_pull_is_actionable_never_a_hang() {
    // the satellite case: the serving worker dies while our pull is in
    // flight — header promises a reply, the body never comes
    let addr = fake_peer(|mut stream| {
        let _hello = wire::read_frame(&mut stream).unwrap();
        let _request = wire::read_frame(&mut stream).unwrap();
        stream.write_all(&1000u32.to_le_bytes()).unwrap();
        stream.flush().unwrap();
        drop(stream); // killed mid-reply
    });
    let mut client =
        PeerClient::new(0, 0, RetryPolicy::once(), &two_worker_book(&addr)).unwrap();
    let err = format!("{:#}", client.fetch(7, 1, &[5, 6], 3, &RowCodec::none()).unwrap_err());
    assert!(err.contains("peer worker 1"), "{err}");
    assert!(err.contains("round 7"), "{err}");
    assert!(err.contains("honest nodes 5..10"), "{err}");
}

#[test]
fn stale_pull_reply_is_rejected() {
    let addr = fake_peer(|stream| {
        let mut t = SocketTransport::from_stream(stream).unwrap();
        let _hello = t.recv().unwrap();
        let _request = t.recv().unwrap();
        // correct shape, wrong round: a stranded reply from round 6
        t.send(&proto::encode_pull_reply(6, &[vec![0.0f32; 3], vec![0.0f32; 3]]))
            .unwrap();
    });
    let mut client =
        PeerClient::new(0, 0, RetryPolicy::once(), &two_worker_book(&addr)).unwrap();
    let err = format!("{:#}", client.fetch(7, 1, &[5, 6], 3, &RowCodec::none()).unwrap_err());
    assert!(err.contains("stale PullReply"), "{err}");
    assert!(err.contains("round 7"), "{err}");
}

#[test]
fn malformed_pull_reply_is_rejected() {
    let addr = fake_peer(|stream| {
        let mut t = SocketTransport::from_stream(stream).unwrap();
        let _hello = t.recv().unwrap();
        let _request = t.recv().unwrap();
        // right round, wrong width: silent corruption must not pass
        t.send(&proto::encode_pull_reply(7, &[vec![0.0f32; 2], vec![0.0f32; 2]]))
            .unwrap();
    });
    let mut client =
        PeerClient::new(0, 0, RetryPolicy::once(), &two_worker_book(&addr)).unwrap();
    let err = format!("{:#}", client.fetch(7, 1, &[5, 6], 3, &RowCodec::none()).unwrap_err());
    assert!(err.contains("malformed PullReply"), "{err}");
}

/// The retry satellite, success path: the first pull dies mid-reply,
/// the policy re-dials from scratch, and the second attempt is served —
/// the caller sees clean rows plus one consumed retry in the ledger.
#[test]
fn pull_retry_redials_and_succeeds_within_budget() {
    let listener = Listener::bind(&SockAddr::Tcp("127.0.0.1:0".into())).unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        // attempt 1: header promises a reply, the body never comes
        let mut s1 = listener.accept().unwrap();
        s1.set_nonblocking(false).unwrap();
        let _hello = wire::read_frame(&mut s1).unwrap();
        let _request = wire::read_frame(&mut s1).unwrap();
        s1.write_all(&1000u32.to_le_bytes()).unwrap();
        s1.flush().unwrap();
        drop(s1);
        // attempt 2: the re-dialed connection is served correctly
        let stream = listener.accept().unwrap();
        stream.set_nonblocking(false).unwrap();
        let mut t = SocketTransport::from_stream(stream).unwrap();
        let _hello = t.recv().unwrap();
        let _request = t.recv().unwrap();
        t.send(&proto::encode_pull_reply(7, &[vec![1.5f32; 3], vec![-2.5f32; 3]]))
            .unwrap();
    });
    let retry = RetryPolicy {
        attempts: 3,
        backoff_ms: 0,
    };
    let mut client = PeerClient::new(0, 0, retry, &two_worker_book(&addr)).unwrap();
    let (rows, bytes) = client.fetch(7, 1, &[5, 6], 3, &RowCodec::none()).unwrap();
    assert_eq!(rows, vec![vec![1.5f32; 3], vec![-2.5f32; 3]]);
    assert!(bytes > 0);
    assert_eq!(client.take_retries(), 1, "exactly one retry consumed");
    assert_eq!(client.take_retries(), 0, "take_retries drains the counter");
}

/// The retry satellite, exhaustion path: every attempt dies mid-reply;
/// the surfaced error names the peer, the round, and how hard the
/// policy tried — and the call returns (never hangs) once the budget
/// is spent.
#[test]
fn pull_retry_budget_exhaustion_names_peer_round_and_attempts() {
    let listener = Listener::bind(&SockAddr::Tcp("127.0.0.1:0".into())).unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        for _ in 0..2 {
            let mut s = listener.accept().unwrap();
            s.set_nonblocking(false).unwrap();
            let _hello = wire::read_frame(&mut s);
            let _request = wire::read_frame(&mut s);
            let _ = s.write_all(&1000u32.to_le_bytes());
            let _ = s.flush();
            drop(s);
        }
    });
    let retry = RetryPolicy {
        attempts: 2,
        backoff_ms: 0,
    };
    let mut client = PeerClient::new(0, 0, retry, &two_worker_book(&addr)).unwrap();
    let err = format!(
        "{:#}",
        client.fetch(7, 1, &[5, 6], 3, &RowCodec::none()).unwrap_err()
    );
    assert!(err.contains("peer worker 1"), "{err}");
    assert!(err.contains("round 7"), "{err}");
    assert!(err.contains("2 attempt"), "should name the spent budget: {err}");
    assert_eq!(client.take_retries(), 1, "the failed re-dial still counts");
}

// ---------------------------------------------------------------------------
// The real RowServer, exercised directly
// ---------------------------------------------------------------------------

fn connect_hello(addr: &SockAddr) -> SocketTransport {
    let mut t = SocketTransport::connect(addr).unwrap();
    t.send(&proto::encode_peer_hello(9, 0, "")).unwrap();
    t
}

#[test]
fn row_server_serves_published_rounds_and_denies_everything_else() {
    let listener = Listener::bind(&SockAddr::Tcp("127.0.0.1:0".into())).unwrap();
    let addr = listener.local_addr().unwrap();
    // worker 3 owns honest nodes 4..6
    let server = RowServer::spawn(listener, 3, 4, 2).unwrap();
    server.publish(5, &[vec![1.0f32, 2.0], vec![3.0, 4.0]], None);

    let mut t = connect_hello(&addr);

    // the happy path: exactly the requested rows, request order
    t.send(&proto::encode_pull_request(5, &[5, 4])).unwrap();
    match proto::decode_peer(&t.recv().unwrap()).unwrap() {
        PeerMsg::PullReply { round, rows } => {
            assert_eq!(round, 5);
            assert_eq!(rows, vec![vec![3.0f32, 4.0], vec![1.0, 2.0]]);
        }
        other => panic!("expected PullReply, got {other:?}"),
    }

    // stale round: denied with the published round named
    t.send(&proto::encode_pull_request(6, &[4])).unwrap();
    match proto::decode_peer(&t.recv().unwrap()).unwrap() {
        PeerMsg::Deny { message } => {
            assert!(message.contains("round 6"), "{message}");
            assert!(message.contains("5"), "{message}");
        }
        other => panic!("expected Deny, got {other:?}"),
    }

    // out-of-range row: denied with the owned range named
    t.send(&proto::encode_pull_request(5, &[9])).unwrap();
    match proto::decode_peer(&t.recv().unwrap()).unwrap() {
        PeerMsg::Deny { message } => {
            assert!(message.contains("4..6"), "{message}");
        }
        other => panic!("expected Deny, got {other:?}"),
    }

    // a republish moves the served round forward
    server.publish(6, &[vec![9.0f32, 9.0], vec![8.0, 8.0]], None);
    t.send(&proto::encode_pull_request(6, &[4])).unwrap();
    match proto::decode_peer(&t.recv().unwrap()).unwrap() {
        PeerMsg::PullReply { round, rows } => {
            assert_eq!(round, 6);
            assert_eq!(rows, vec![vec![9.0f32, 9.0]]);
        }
        other => panic!("expected PullReply, got {other:?}"),
    }
}

#[test]
fn row_server_rejects_wrong_version_handshake() {
    let listener = Listener::bind(&SockAddr::Tcp("127.0.0.1:0".into())).unwrap();
    let addr = listener.local_addr().unwrap();
    let _server = RowServer::spawn(listener, 0, 0, 1).unwrap();

    let mut t = SocketTransport::connect(&addr).unwrap();
    let mut bad_hello = proto::encode_peer_hello(1, 0, "x");
    bad_hello[1] ^= 0x7F; // corrupt the version field
    t.send(&bad_hello).unwrap();
    match proto::decode_peer(&t.recv().unwrap()).unwrap() {
        PeerMsg::Deny { message } => {
            assert!(message.contains("version mismatch"), "{message}");
        }
        other => panic!("expected Deny, got {other:?}"),
    }
    // the server then drops the connection: EOF, not a hang
    assert!(t.recv_opt().unwrap().is_none());
}

#[cfg(unix)]
#[test]
fn row_server_works_over_unix_sockets_too() {
    let dir = std::env::temp_dir().join(format!("rpel-faults-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let listener = Listener::bind(&SockAddr::Unix(dir.join("serve.sock"))).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = RowServer::spawn(listener, 0, 0, 1).unwrap();
    server.publish(2, &[vec![7.5f32]], None);

    let mut t = connect_hello(&addr);
    t.send(&proto::encode_pull_request(2, &[0])).unwrap();
    match proto::decode_peer(&t.recv().unwrap()).unwrap() {
        PeerMsg::PullReply { round, rows } => {
            assert_eq!((round, rows), (2, vec![vec![7.5f32]]));
        }
        other => panic!("expected PullReply, got {other:?}"),
    }
    drop(t);
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
}
