//! Multi-process shard-engine failure modes: a worker that dies
//! mid-round must surface as an **actionable error** on the coordinator
//! — naming the worker, its honest range, and its exit status — never a
//! hang; and the `rpel shard-worker` subcommand must be robust against a
//! garbage or closed stream. Both transports are covered: pipes (the
//! worker's stdin/stdout) and sockets (worker-served pulls, where a
//! killed worker also strands its peers' in-flight pulls).

use rpel::config::{ExperimentConfig, Topology, TransportKind};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use std::io::Write;
use std::process::{Command, Stdio};

const WORKER_BIN: &str = env!("CARGO_BIN_EXE_rpel");

fn enable_worker_bin() {
    // OnceLock-backed hook: env::set_var would race with the sibling
    // tests that are concurrently Command::spawn-ing workers
    rpel::coordinator::proc::set_worker_bin(WORKER_BIN);
}

fn proc_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = "proc_crash".into();
    cfg.n = 10;
    cfg.b = 2;
    cfg.topology = Topology::Epidemic { s: 5 };
    cfg.bhat = Some(2);
    cfg.rounds = 50;
    cfg.batch = 8;
    cfg.samples_per_node = 32;
    cfg.test_samples = 64;
    cfg.eval_every = 100;
    cfg.procs = 2;
    cfg.threads = 1;
    cfg
}

#[test]
fn killed_worker_surfaces_actionable_error_not_a_hang() {
    enable_worker_bin();
    let cfg = proc_cfg();
    let mut t = Trainer::from_config(&cfg).expect("multi-process trainer builds");
    assert_eq!(t.shard_count(), 2);
    // one healthy round first, so the kill lands mid-run
    t.round(0).expect("healthy round");

    assert!(t.kill_shard_worker(1), "worker 1 should be killable");
    let mut failure = None;
    for round in 1..cfg.rounds {
        if let Err(e) = t.round(round) {
            failure = Some(format!("{e:#}"));
            break;
        }
    }
    let msg = failure.expect("rounds must fail after the worker died");
    assert!(
        msg.contains("shard worker 1"),
        "error should name the dead worker: {msg}"
    );
    assert!(
        msg.contains("honest nodes"),
        "error should name the orphaned range: {msg}"
    );
}

#[test]
fn killed_socket_worker_surfaces_actionable_error_not_a_hang() {
    // socket-transport teardown audit: the killed worker's control
    // socket AND its peers' pull connections die with it — whichever
    // side trips first, the coordinator must report a named shard
    // worker, and the run must never wedge (a peer blocked on a pull to
    // the corpse would be exactly that)
    enable_worker_bin();
    let mut cfg = proc_cfg();
    cfg.name = "proc_crash_socket".into();
    cfg.transport = TransportKind::Socket;
    let mut t = Trainer::from_config(&cfg).expect("socket-transport trainer builds");
    assert_eq!(t.shard_count(), 2);
    t.round(0).expect("healthy round");

    assert!(t.kill_shard_worker(1), "worker 1 should be killable");
    let mut failure = None;
    for round in 1..cfg.rounds {
        if let Err(e) = t.round(round) {
            failure = Some(format!("{e:#}"));
            break;
        }
    }
    let msg = failure.expect("rounds must fail after the worker died");
    assert!(
        msg.contains("shard worker") || msg.contains("peer worker"),
        "error should name the dead worker: {msg}"
    );
    drop(t); // teardown with a corpse in the pool must not deadlock
}

/// Tentpole part 2 end-to-end (pipe transport): a shard worker killed
/// mid-run is respawned by the supervisor at the last completed round
/// boundary and the failed round is re-driven. The finished trajectory
/// must be bit-identical to the unfaulted run on every ledger except
/// the restart counter itself (and wall time, which is reporting-only).
#[test]
fn supervised_restart_replays_trajectory_bit_identically() {
    enable_worker_bin();
    let mut cfg = proc_cfg();
    cfg.name = "proc_recovery_pipe".into();
    cfg.rounds = 6;
    let reference = Trainer::from_config(&cfg).unwrap().run().unwrap();
    assert!(
        reference.worker_restarts_per_round.iter().all(|&r| r == 0),
        "unfaulted run must consume no restarts"
    );

    let mut t = Trainer::from_config(&cfg).unwrap();
    t.chaos_kill_at(3, 1);
    let faulted = t.run().expect("supervised run recovers from the kill");
    assert_eq!(
        faulted.worker_restarts_per_round[3], 1,
        "exactly one respawn, charged to the faulted round"
    );

    let mut a = reference.clone();
    let mut b = faulted;
    a.wall_secs = 0.0;
    b.wall_secs = 0.0;
    a.worker_restarts_per_round.clear();
    b.worker_restarts_per_round.clear();
    assert_eq!(a, b, "recovered trajectory must match the unfaulted run");
}

/// Same contract over sockets, where a respawn also moves the worker's
/// peer listener: the supervisor re-broadcasts the address book, the
/// survivor rebuilds its fetch client, and the re-driven round's pulls
/// land on the fresh incarnation.
#[test]
fn supervised_socket_restart_replays_trajectory_bit_identically() {
    enable_worker_bin();
    let mut cfg = proc_cfg();
    cfg.name = "proc_recovery_socket".into();
    cfg.transport = TransportKind::Socket;
    cfg.rounds = 6;
    let reference = Trainer::from_config(&cfg).unwrap().run().unwrap();

    let mut t = Trainer::from_config(&cfg).unwrap();
    t.chaos_kill_at(2, 0);
    let faulted = t.run().expect("supervised socket run recovers from the kill");
    assert_eq!(faulted.worker_restarts_per_round[2], 1);

    let mut a = reference.clone();
    let mut b = faulted;
    a.wall_secs = 0.0;
    b.wall_secs = 0.0;
    a.worker_restarts_per_round.clear();
    b.worker_restarts_per_round.clear();
    assert_eq!(a, b, "recovered trajectory must match the unfaulted run");
}

/// Restart budget exhaustion: the supervisor declines the respawn and
/// the run fails with the pre-recovery named error — never a hang.
#[test]
fn restart_budget_exhaustion_surfaces_named_error_not_a_hang() {
    enable_worker_bin();
    let mut cfg = proc_cfg();
    cfg.name = "proc_budget".into();
    cfg.rounds = 10;
    cfg.recovery.max_worker_restarts = 1;
    let mut t = Trainer::from_config(&cfg).unwrap();
    t.chaos_kill_at(2, 1);
    t.chaos_kill_at(4, 1); // second kill exceeds the budget of 1
    let err = t.run().expect_err("second kill must exhaust the budget");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("shard worker 1"),
        "budget exhaustion should surface the named worker error: {msg}"
    );
}

/// `max_worker_restarts = 0` pins the pre-recovery contract inside the
/// full run loop: no supervision, no state-sync traffic, and the first
/// worker death is fatal with the named error.
#[test]
fn unsupervised_run_fails_fast_on_worker_death() {
    enable_worker_bin();
    let mut cfg = proc_cfg();
    cfg.name = "proc_unsupervised".into();
    cfg.rounds = 10;
    cfg.recovery.max_worker_restarts = 0;
    let mut t = Trainer::from_config(&cfg).unwrap();
    t.chaos_kill_at(1, 0);
    let err = t.run().expect_err("unsupervised worker death must be fatal");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("shard worker 0"),
        "error should name the dead worker: {msg}"
    );
}

#[test]
fn socket_trainer_tears_down_cleanly_mid_run() {
    // Drop with live workers (socket transport): Shutdown frames, a
    // half-close + drain per worker, reap — the test completing IS the
    // no-deadlock assertion
    enable_worker_bin();
    let mut cfg = proc_cfg();
    cfg.name = "proc_teardown_socket".into();
    cfg.transport = TransportKind::Socket;
    let mut t = Trainer::from_config(&cfg).unwrap();
    t.round(0).unwrap();
    drop(t);
}

/// The ISSUE satellite end-to-end, with REAL worker processes: the test
/// plays coordinator over sockets, completes one routed round (so
/// worker 0 holds a live pull connection to worker 1), then kills
/// worker 1 and routes another round's pulls through the corpse. Worker
/// 0's in-flight pull must come back as `Failed` naming the peer worker
/// and the round — never a hang, never silent corruption.
#[test]
fn real_socket_peer_pull_to_killed_worker_returns_failed() {
    use rpel::wire::proto::{self, FromWorker, PeerEntry, PeerMsg, ToWorker, WireDigest};
    use rpel::wire::transport::{Listener, SockAddr, SocketTransport, Transport};

    // b = 0 keeps the routing arbitrary (node id == honest index) and
    // the digest unused; procs = 2 splits h = 6 as (0..3, 3..6)
    const CFG: &str = "task = \"tiny\"\n\n[nodes]\nn = 6\nbyzantine = 0\n\n\
                       [topology]\nkind = \"epidemic\"\ns = 3\n";

    let listener = Listener::bind(&SockAddr::Tcp("127.0.0.1:0".into())).unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let spawn_worker = |i: usize| {
        Command::new(WORKER_BIN)
            .arg("shard-worker")
            .arg("--transport")
            .arg("socket")
            .arg("--connect")
            .arg(&addr)
            .arg("--worker")
            .arg(i.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn shard-worker")
    };
    let mut children = vec![spawn_worker(0), spawn_worker(1)];

    // accept both control connections, identified by PeerHello
    let mut conns: Vec<Option<SocketTransport>> = vec![None, None];
    let mut listens = vec![String::new(); 2];
    for _ in 0..2 {
        let stream = listener.accept().unwrap();
        let mut t = SocketTransport::from_stream(stream).unwrap();
        match proto::decode_peer(&t.recv().unwrap()).unwrap() {
            PeerMsg::Hello { worker, listen, .. } => {
                let w = worker as usize;
                listens[w] = listen;
                conns[w] = Some(t);
            }
            other => panic!("expected PeerHello, got {other:?}"),
        }
    }
    let mut w0 = conns[0].take().unwrap();
    let mut w1 = conns[1].take().unwrap();

    let fresh = proto::WireResume::default();
    w0.send(&proto::encode_init(CFG, 0, 2, &fresh)).unwrap();
    w1.send(&proto::encode_init(CFG, 1, 2, &fresh)).unwrap();
    let init_ok = |t: &mut SocketTransport| match proto::decode_from_worker(&t.recv().unwrap())
        .unwrap()
    {
        FromWorker::InitOk { start, len, d: _ } => (start, len),
        other => panic!("expected InitOk, got {other:?}"),
    };
    assert_eq!(init_ok(&mut w0), (0, 3));
    assert_eq!(init_ok(&mut w1), (3, 3));

    let book = proto::encode_peers(&[
        PeerEntry {
            start: 0,
            len: 3,
            addr: listens[0].clone(),
        },
        PeerEntry {
            start: 3,
            len: 3,
            addr: listens[1].clone(),
        },
    ]);
    w0.send(&book).unwrap();
    w1.send(&book).unwrap();

    let half = |t: &mut SocketTransport, round: u64| {
        t.send(&proto::encode_half_step(round)).unwrap();
        match proto::decode_from_worker(&t.recv().unwrap()).unwrap() {
            FromWorker::Snapshot { round: got, .. } => assert_eq!(got, round),
            other => panic!("expected Snapshot, got {other:?}"),
        }
    };
    let routed = |round: u64, routes: Vec<Vec<u32>>| {
        proto::encode_to_worker(&ToWorker::AggregateRouted {
            round,
            digest: WireDigest::default(),
            routes,
        })
    };

    // round 0 completes: worker 0 pulls worker 1's rows (establishing
    // the persistent peer connection), worker 1 pulls nothing
    half(&mut w0, 0);
    half(&mut w1, 0);
    w0.send(&routed(0, vec![vec![3], vec![4], vec![5]])).unwrap();
    w1.send(&routed(0, vec![vec![], vec![], vec![]])).unwrap();
    let done = |t: &mut SocketTransport, round: u64| match proto::decode_from_worker(
        &t.recv().unwrap(),
    )
    .unwrap()
    {
        FromWorker::RoundDone {
            round: got,
            peer_bytes,
            ..
        } => {
            assert_eq!(got, round);
            peer_bytes
        }
        other => panic!("expected RoundDone, got {other:?}"),
    };
    assert!(done(&mut w0, 0) > 0, "worker 0 must have fetched from its peer");
    done(&mut w1, 0);

    // round 1: half-steps land, then worker 1 dies with worker 0's next
    // pull aimed straight at it over the already-open connection
    half(&mut w0, 1);
    half(&mut w1, 1);
    children[1].kill().unwrap();
    children[1].wait().unwrap();
    w0.send(&routed(1, vec![vec![3], vec![4], vec![5]])).unwrap();
    match proto::decode_from_worker(&w0.recv().unwrap()).unwrap() {
        FromWorker::Failed { message } => {
            assert!(message.contains("peer worker 1"), "{message}");
            assert!(message.contains("round 1"), "{message}");
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    drop(w0);
    drop(w1);
    let status = children[0].wait().unwrap();
    assert!(!status.success(), "worker 0 exits nonzero after the failed pull");
}

#[test]
fn socket_worker_with_unreachable_coordinator_exits_nonzero() {
    let status = Command::new(WORKER_BIN)
        .arg("shard-worker")
        .arg("--transport")
        .arg("socket")
        .arg("--connect")
        .arg("unix:/nonexistent-rpel-dir/coordinator.sock")
        .arg("--worker")
        .arg("0")
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .status()
        .expect("spawn shard-worker");
    assert!(!status.success(), "dead coordinator address must be fatal");
}

#[test]
fn in_process_backends_are_not_killable() {
    let mut cfg = proc_cfg();
    cfg.procs = 1;
    cfg.shards = 2;
    cfg.rounds = 2;
    let mut t = Trainer::from_config(&cfg).unwrap();
    assert!(!t.kill_shard_worker(0));
    assert!(!t.kill_shard_worker(99));
    t.run().unwrap();
}

#[test]
fn worker_rejects_garbage_stream_without_hanging() {
    let mut child = Command::new(WORKER_BIN)
        .arg("shard-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn shard-worker");
    // an absurd frame-length header: must be rejected, not allocated
    // (ignore write errors — the worker may exit before the write lands)
    let _ = child.stdin.take().unwrap().write_all(&[0xFF; 64]);
    let status = child.wait().expect("worker exits");
    assert!(!status.success(), "garbage stream must be a failure");
}

#[test]
fn worker_exits_cleanly_on_immediate_eof() {
    let mut child = Command::new(WORKER_BIN)
        .arg("shard-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn shard-worker");
    drop(child.stdin.take()); // close before Init: an orderly no-op
    let status = child.wait().expect("worker exits");
    assert!(status.success(), "EOF before Init is a clean shutdown");
}

#[test]
fn worker_reports_bad_config_instead_of_dying_silently() {
    use rpel::wire;
    use rpel::wire::proto::{self, FromWorker};

    let mut child = Command::new(WORKER_BIN)
        .arg("shard-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn shard-worker");
    let mut stdin = child.stdin.take().unwrap();
    wire::write_frame(
        &mut stdin,
        &proto::encode_init(
            "task = \"not_a_task\"",
            0,
            2,
            &proto::WireResume::default(),
        ),
    )
    .unwrap();
    stdin.flush().unwrap();
    let mut stdout = child.stdout.take().unwrap();
    let frame = wire::read_frame(&mut stdout).expect("worker replies before exiting");
    match proto::decode_from_worker(&frame).unwrap() {
        FromWorker::Failed { message } => {
            assert!(message.contains("bad config"), "{message}");
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    drop(stdin);
    let status = child.wait().unwrap();
    assert!(!status.success());
}
