//! Multi-process shard-engine failure modes: a worker that dies
//! mid-round must surface as an **actionable error** on the coordinator
//! — naming the worker, its honest range, and its exit status — never a
//! hang; and the `rpel shard-worker` subcommand must be robust against a
//! garbage or closed stream.

use rpel::config::{ExperimentConfig, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use std::io::Write;
use std::process::{Command, Stdio};

const WORKER_BIN: &str = env!("CARGO_BIN_EXE_rpel");

fn enable_worker_bin() {
    // OnceLock-backed hook: env::set_var would race with the sibling
    // tests that are concurrently Command::spawn-ing workers
    rpel::coordinator::proc::set_worker_bin(WORKER_BIN);
}

fn proc_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = "proc_crash".into();
    cfg.n = 10;
    cfg.b = 2;
    cfg.topology = Topology::Epidemic { s: 5 };
    cfg.bhat = Some(2);
    cfg.rounds = 50;
    cfg.batch = 8;
    cfg.samples_per_node = 32;
    cfg.test_samples = 64;
    cfg.eval_every = 100;
    cfg.procs = 2;
    cfg.threads = 1;
    cfg
}

#[test]
fn killed_worker_surfaces_actionable_error_not_a_hang() {
    enable_worker_bin();
    let cfg = proc_cfg();
    let mut t = Trainer::from_config(&cfg).expect("multi-process trainer builds");
    assert_eq!(t.shard_count(), 2);
    // one healthy round first, so the kill lands mid-run
    t.round(0).expect("healthy round");

    assert!(t.kill_shard_worker(1), "worker 1 should be killable");
    let mut failure = None;
    for round in 1..cfg.rounds {
        if let Err(e) = t.round(round) {
            failure = Some(format!("{e:#}"));
            break;
        }
    }
    let msg = failure.expect("rounds must fail after the worker died");
    assert!(
        msg.contains("shard worker 1"),
        "error should name the dead worker: {msg}"
    );
    assert!(
        msg.contains("honest nodes"),
        "error should name the orphaned range: {msg}"
    );
}

#[test]
fn in_process_backends_are_not_killable() {
    let mut cfg = proc_cfg();
    cfg.procs = 1;
    cfg.shards = 2;
    cfg.rounds = 2;
    let mut t = Trainer::from_config(&cfg).unwrap();
    assert!(!t.kill_shard_worker(0));
    assert!(!t.kill_shard_worker(99));
    t.run().unwrap();
}

#[test]
fn worker_rejects_garbage_stream_without_hanging() {
    let mut child = Command::new(WORKER_BIN)
        .arg("shard-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn shard-worker");
    // an absurd frame-length header: must be rejected, not allocated
    // (ignore write errors — the worker may exit before the write lands)
    let _ = child.stdin.take().unwrap().write_all(&[0xFF; 64]);
    let status = child.wait().expect("worker exits");
    assert!(!status.success(), "garbage stream must be a failure");
}

#[test]
fn worker_exits_cleanly_on_immediate_eof() {
    let mut child = Command::new(WORKER_BIN)
        .arg("shard-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn shard-worker");
    drop(child.stdin.take()); // close before Init: an orderly no-op
    let status = child.wait().expect("worker exits");
    assert!(status.success(), "EOF before Init is a clean shutdown");
}

#[test]
fn worker_reports_bad_config_instead_of_dying_silently() {
    use rpel::wire;
    use rpel::wire::proto::{self, FromWorker};

    let mut child = Command::new(WORKER_BIN)
        .arg("shard-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn shard-worker");
    let mut stdin = child.stdin.take().unwrap();
    wire::write_frame(
        &mut stdin,
        &proto::encode_init("task = \"not_a_task\"", 0, 2),
    )
    .unwrap();
    stdin.flush().unwrap();
    let mut stdout = child.stdout.take().unwrap();
    let frame = wire::read_frame(&mut stdout).expect("worker replies before exiting");
    match proto::decode_from_worker(&frame).unwrap() {
        FromWorker::Failed { message } => {
            assert!(message.contains("bad config"), "{message}");
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    drop(stdin);
    let status = child.wait().unwrap();
    assert!(!status.success());
}
