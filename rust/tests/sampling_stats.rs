//! Statistical validation of the pull sampler and the hypergeometric
//! sampler. All draws come from fixed seeds / fixed counter-based stream
//! keys, so each assertion is deterministic; the bounds are set many
//! standard deviations beyond what a correct sampler can produce, so a
//! failure means a real distributional bug, not noise.

use rpel::config::{AsyncCfg, StragglerKind};
use rpel::coordinator::PullSampler;
use rpel::sampling::Hypergeometric;
use rpel::util::rng::Rng;
use rpel::util::special::normal_cdf;
use rpel::util::vclock::sample_latency;

/// Pearson chi-square statistic against per-cell expected counts.
fn chi_square(observed: &[u64], expected: &[f64]) -> f64 {
    observed
        .iter()
        .zip(expected)
        .map(|(&o, &e)| {
            let d = o as f64 - e;
            d * d / e
        })
        .sum()
}

#[test]
fn pull_frequencies_uniform_over_peers() {
    // over many stream-keyed rounds, every peer of every victim must be
    // pulled with frequency s/(n-1): chi-square per victim, df = n-2 = 10.
    // E[chi2] = 10, sd ≈ 4.5; 60 is ~11 sigma.
    let (n, s, rounds, seed) = (12usize, 4usize, 20_000usize, 2026u64);
    let sampler = PullSampler::new(n, s);
    for victim in 0..n {
        let mut counts = vec![0u64; n];
        for round in 0..rounds {
            let set = sampler.sample_at(seed, round, victim);
            assert_eq!(set.len(), s);
            let mut dedup = set.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), s, "duplicate peer in round {round}");
            for p in set {
                counts[p] += 1;
            }
        }
        assert_eq!(counts[victim], 0, "victim {victim} sampled itself");
        let observed: Vec<u64> = (0..n).filter(|&p| p != victim).map(|p| counts[p]).collect();
        let expect = rounds as f64 * s as f64 / (n - 1) as f64;
        let expected = vec![expect; n - 1];
        let chi2 = chi_square(&observed, &expected);
        assert!(
            chi2 < 60.0,
            "victim {victim}: chi2 = {chi2:.1} over {observed:?}"
        );
    }
}

#[test]
fn byzantine_exposure_matches_hypergeometric_law() {
    // with b Byzantine among the other n-1 peers, the number of malicious
    // rows a victim pulls is HG(n-1, b, s) — the distribution Lemma 4.1
    // and Algorithm 2 are built on. Chi-square over the full support.
    let (n, b, s, rounds, seed) = (20usize, 4usize, 8usize, 15_000usize, 7u64);
    let sampler = PullSampler::new(n, s);
    let victim = n - 1; // Byzantine ids: 0..b (victim is honest)
    let mut hits = vec![0u64; b + 1];
    for round in 0..rounds {
        let k = sampler
            .sample_at(seed, round, victim)
            .into_iter()
            .filter(|&p| p < b)
            .count();
        hits[k] += 1;
    }
    let hg = Hypergeometric::new((n - 1) as u64, b as u64, s as u64);
    let expected: Vec<f64> = (0..=b).map(|k| rounds as f64 * hg.pmf(k as u64)).collect();
    assert!(expected.iter().all(|&e| e > 5.0), "degenerate test setup");
    let chi2 = chi_square(&hits, &expected);
    // df = 4: E[chi2] = 4, sd ≈ 2.8; 40 is ~13 sigma
    assert!(chi2 < 40.0, "chi2 = {chi2:.1}, hits {hits:?} vs {expected:?}");
}

#[test]
fn hypergeometric_sampler_matches_exact_cdf() {
    // the Rng's sequential-draw sampler against the closed-form CDF:
    // sup-distance of the empirical CDF (KS ~ 0.008 expected at this N;
    // 0.02 is far outside what a correct sampler can reach)
    let (total, marked, draws) = (30u64, 10u64, 8u64);
    let n_samples = 40_000usize;
    let mut rng = Rng::new(99);
    let mut counts = vec![0u64; (draws + 1) as usize];
    for _ in 0..n_samples {
        let k = rng.hypergeometric(total, marked, draws);
        counts[k as usize] += 1;
    }
    let hg = Hypergeometric::new(total, marked, draws);
    let mut cum = 0u64;
    let mut worst = 0.0f64;
    let mut mean_emp = 0.0f64;
    for k in 0..=draws {
        cum += counts[k as usize];
        mean_emp += k as f64 * counts[k as usize] as f64 / n_samples as f64;
        let emp = cum as f64 / n_samples as f64;
        worst = worst.max((emp - hg.cdf(k)).abs());
    }
    assert!(worst < 0.02, "KS distance {worst:.4}");
    assert!(
        (mean_emp - hg.mean()).abs() < 0.05,
        "empirical mean {mean_emp:.3} vs exact {:.3}",
        hg.mean()
    );
}

// ---------------------------------------------------------------------------
// Straggler latency distributions (the async engine's virtual clock)
// ---------------------------------------------------------------------------

#[test]
fn lognormal_latency_matches_the_analytic_cdf() {
    // inverse-CDF sampling over counter-keyed streams against the exact
    // law: lat = base * exp(sigma * PhiInv(u)), so
    // F(x) = Phi(ln(x / base) / sigma). KS sup-distance ~ 0.004 expected
    // at this N; 0.02 is far outside what a correct sampler can reach.
    let cfg = AsyncCfg {
        straggler: StragglerKind::LogNormal,
        base_latency: 2.0,
        sigma: 0.5,
        ..AsyncCfg::default()
    };
    let (seed, rounds, nodes) = (2026u64, 200u64, 200u64);
    let n = (rounds * nodes) as usize;
    let mut samples = Vec::with_capacity(n);
    for round in 1..=rounds {
        for node in 0..nodes {
            let lat = sample_latency(&cfg, seed, round, node);
            assert!(lat.is_finite() && lat > 0.0, "lat = {lat}");
            samples.push(lat);
        }
    }
    samples.sort_unstable_by(f64::total_cmp);
    let mut worst = 0.0f64;
    let mut mean_ln = 0.0f64;
    for (i, &x) in samples.iter().enumerate() {
        let z = (x / cfg.base_latency).ln() / cfg.sigma;
        mean_ln += z / n as f64;
        let f = normal_cdf(z);
        worst = worst
            .max((f - i as f64 / n as f64).abs())
            .max((f - (i + 1) as f64 / n as f64).abs());
    }
    assert!(worst < 0.02, "KS distance {worst:.4}");
    // ln(lat/base)/sigma is standard normal: mean 0 +/- 1/sqrt(N)
    assert!(mean_ln.abs() < 0.02, "mean z = {mean_ln:.4}");
}

#[test]
fn two_point_latency_frequencies_are_exact() {
    // every draw is bit-exactly the fast or the slow latency, and the
    // slow fraction matches slow_prob: chi-square over 2 cells, df = 1.
    // E[chi2] = 1, sd ~ 1.4; 30 is many sigma out.
    let cfg = AsyncCfg {
        straggler: StragglerKind::TwoPoint,
        base_latency: 1.0,
        slow_prob: 0.25,
        slow_latency: 4.0,
        ..AsyncCfg::default()
    };
    let (seed, rounds, nodes) = (7u64, 200u64, 200u64);
    let n = rounds * nodes;
    let mut slow = 0u64;
    for round in 1..=rounds {
        for node in 0..nodes {
            let lat = sample_latency(&cfg, seed, round, node);
            if lat.to_bits() == cfg.slow_latency.to_bits() {
                slow += 1;
            } else {
                assert_eq!(
                    lat.to_bits(),
                    cfg.base_latency.to_bits(),
                    "two-point draw off-support: {lat}"
                );
            }
        }
    }
    let expected = [
        n as f64 * (1.0 - cfg.slow_prob),
        n as f64 * cfg.slow_prob,
    ];
    let chi2 = chi_square(&[n - slow, slow], &expected);
    assert!(chi2 < 30.0, "chi2 = {chi2:.1} (slow {slow}/{n})");
}

#[test]
fn constant_latency_is_seed_independent_and_exact() {
    // the neutral distribution draws nothing: bit-exactly base_latency
    // for every key, under every seed (this is what makes quorum = h
    // collapse to the synchronous engine)
    let cfg = AsyncCfg {
        base_latency: 1.5,
        ..AsyncCfg::default()
    };
    for seed in [0u64, 1, 2026] {
        for round in 1..=50u64 {
            for node in 0..20u64 {
                assert_eq!(
                    sample_latency(&cfg, seed, round, node).to_bits(),
                    1.5f64.to_bits()
                );
            }
        }
    }
}
