//! Statistical validation of the pull sampler and the hypergeometric
//! sampler. All draws come from fixed seeds / fixed counter-based stream
//! keys, so each assertion is deterministic; the bounds are set many
//! standard deviations beyond what a correct sampler can produce, so a
//! failure means a real distributional bug, not noise.

use rpel::coordinator::PullSampler;
use rpel::sampling::Hypergeometric;
use rpel::util::rng::Rng;

/// Pearson chi-square statistic against per-cell expected counts.
fn chi_square(observed: &[u64], expected: &[f64]) -> f64 {
    observed
        .iter()
        .zip(expected)
        .map(|(&o, &e)| {
            let d = o as f64 - e;
            d * d / e
        })
        .sum()
}

#[test]
fn pull_frequencies_uniform_over_peers() {
    // over many stream-keyed rounds, every peer of every victim must be
    // pulled with frequency s/(n-1): chi-square per victim, df = n-2 = 10.
    // E[chi2] = 10, sd ≈ 4.5; 60 is ~11 sigma.
    let (n, s, rounds, seed) = (12usize, 4usize, 20_000usize, 2026u64);
    let sampler = PullSampler::new(n, s);
    for victim in 0..n {
        let mut counts = vec![0u64; n];
        for round in 0..rounds {
            let set = sampler.sample_at(seed, round, victim);
            assert_eq!(set.len(), s);
            let mut dedup = set.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), s, "duplicate peer in round {round}");
            for p in set {
                counts[p] += 1;
            }
        }
        assert_eq!(counts[victim], 0, "victim {victim} sampled itself");
        let observed: Vec<u64> = (0..n).filter(|&p| p != victim).map(|p| counts[p]).collect();
        let expect = rounds as f64 * s as f64 / (n - 1) as f64;
        let expected = vec![expect; n - 1];
        let chi2 = chi_square(&observed, &expected);
        assert!(
            chi2 < 60.0,
            "victim {victim}: chi2 = {chi2:.1} over {observed:?}"
        );
    }
}

#[test]
fn byzantine_exposure_matches_hypergeometric_law() {
    // with b Byzantine among the other n-1 peers, the number of malicious
    // rows a victim pulls is HG(n-1, b, s) — the distribution Lemma 4.1
    // and Algorithm 2 are built on. Chi-square over the full support.
    let (n, b, s, rounds, seed) = (20usize, 4usize, 8usize, 15_000usize, 7u64);
    let sampler = PullSampler::new(n, s);
    let victim = n - 1; // Byzantine ids: 0..b (victim is honest)
    let mut hits = vec![0u64; b + 1];
    for round in 0..rounds {
        let k = sampler
            .sample_at(seed, round, victim)
            .into_iter()
            .filter(|&p| p < b)
            .count();
        hits[k] += 1;
    }
    let hg = Hypergeometric::new((n - 1) as u64, b as u64, s as u64);
    let expected: Vec<f64> = (0..=b).map(|k| rounds as f64 * hg.pmf(k as u64)).collect();
    assert!(expected.iter().all(|&e| e > 5.0), "degenerate test setup");
    let chi2 = chi_square(&hits, &expected);
    // df = 4: E[chi2] = 4, sd ≈ 2.8; 40 is ~13 sigma
    assert!(chi2 < 40.0, "chi2 = {chi2:.1}, hits {hits:?} vs {expected:?}");
}

#[test]
fn hypergeometric_sampler_matches_exact_cdf() {
    // the Rng's sequential-draw sampler against the closed-form CDF:
    // sup-distance of the empirical CDF (KS ~ 0.008 expected at this N;
    // 0.02 is far outside what a correct sampler can reach)
    let (total, marked, draws) = (30u64, 10u64, 8u64);
    let n_samples = 40_000usize;
    let mut rng = Rng::new(99);
    let mut counts = vec![0u64; (draws + 1) as usize];
    for _ in 0..n_samples {
        let k = rng.hypergeometric(total, marked, draws);
        counts[k as usize] += 1;
    }
    let hg = Hypergeometric::new(total, marked, draws);
    let mut cum = 0u64;
    let mut worst = 0.0f64;
    let mut mean_emp = 0.0f64;
    for k in 0..=draws {
        cum += counts[k as usize];
        mean_emp += k as f64 * counts[k as usize] as f64 / n_samples as f64;
        let emp = cum as f64 / n_samples as f64;
        worst = worst.max((emp - hg.cdf(k)).abs());
    }
    assert!(worst < 0.02, "KS distance {worst:.4}");
    assert!(
        (mean_emp - hg.mean()).abs() < 0.05,
        "empirical mean {mean_emp:.3} vs exact {:.3}",
        hg.mean()
    );
}
