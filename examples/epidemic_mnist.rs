//! The paper's headline experiment (Figure 1 left): MNIST-like task,
//! n = 100 nodes with 10% omniscient Byzantine nodes, pull-based epidemic
//! sampling with only s = 15 of 99 possible peers, and the full attack
//! panel (no-attack / SF / FOE / ALIE).
//!
//! This is the END-TO-END VALIDATION driver: it trains a real model per
//! honest node for a few hundred rounds on the (synthetic-)MNIST workload,
//! logs the loss/accuracy curves, and prints the paper-style comparison.
//! EXPERIMENTS.md records a run of this binary.
//!
//! Run:  cargo run --release --example epidemic_mnist [-- --scale paper --engine hlo]
//! Tiny scale (default) finishes in well under a minute on one core.

use rpel::cli::Args;
use rpel::config::presets::{self, Scale};
use rpel::config::EngineKind;
use rpel::experiments;
use rpel::metrics::write_histories;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let scale = Scale::parse(args.get_or("scale", "tiny")).expect("scale tiny|paper");
    let engine = args
        .get("engine")
        .map(|e| EngineKind::parse(e).expect("engine hlo|native"));

    let fig = presets::figure("fig1L").unwrap();
    println!("reproducing {} — {}", fig.id, fig.title);
    println!("expectation: {}\n", fig.expectation);

    let presets::FigureSeries::Training(mut cfgs) = fig.series(scale) else {
        unreachable!()
    };
    let mut histories = Vec::new();
    for cfg in &mut cfgs {
        if let Some(e) = engine {
            cfg.engine = e;
        }
        println!(
            "running {} (n={} b={} {:?} rounds={}, engine={})",
            cfg.name,
            cfg.n,
            cfg.b,
            cfg.topology,
            cfg.rounds,
            cfg.engine.name()
        );
        let hist = experiments::run_training(cfg)?;
        // loss curve (the end-to-end validation requirement)
        print!("  loss curve: ");
        let stride = (hist.train_loss.len() / 8).max(1);
        for (i, l) in hist.train_loss.iter().enumerate().step_by(stride) {
            print!("t{i}:{l:.3} ");
        }
        println!();
        histories.push(hist);
    }

    println!("\n=== paper-style summary (Figure 1 left) ===");
    let no_attack = histories[0].final_avg_accuracy();
    for h in &histories {
        println!(
            "{:<18} final={:.3}  (gap to no-attack: {:+.3})",
            h.name,
            h.final_avg_accuracy(),
            h.final_avg_accuracy() - no_attack
        );
    }
    let paths = write_histories("results/epidemic_mnist", &histories)?;
    println!("\ncsv written: {}", paths.join(", "));
    Ok(())
}
