//! Quickstart: the smallest end-to-end RPEL run.
//!
//! 8 nodes, 1 Byzantine (sign-flipping), pull-based epidemic sampling with
//! s = 7, NNM∘CWTM aggregation. Uses the AOT/Pallas path when artifacts
//! are built (`make artifacts`), the native twin otherwise.
//!
//! Run: `cargo run --release --example quickstart`

use rpel::config::presets;
use rpel::config::EngineKind;
use rpel::coordinator::Trainer;
use rpel::runtime::artifacts_available;

fn main() -> anyhow::Result<()> {
    let mut cfg = presets::quickstart_config();
    if artifacts_available(&cfg.artifacts_dir) {
        cfg.engine = EngineKind::Hlo;
        println!("engine: HLO/PJRT (AOT artifacts found)");
    } else {
        println!("engine: native (run `make artifacts` for the HLO path)");
    }

    let mut trainer = Trainer::from_config(&cfg)?;
    println!(
        "nodes: {} ({} honest, {} Byzantine: {:?})",
        cfg.n,
        trainer.honest_count(),
        cfg.b,
        trainer.byzantine_ids()
    );
    println!(
        "aggregation: {} with b̂ = {} (effective adversarial fraction {:.2})",
        trainer.aggregation_name(),
        trainer.bhat,
        trainer.bhat as f64 / 8.0
    );

    let history = trainer.run()?;
    println!("\nround  avg_acc  worst_acc  loss");
    for e in &history.evals {
        println!(
            "{:>5}  {:>7.3}  {:>9.3}  {:>5.3}",
            e.round, e.avg_acc, e.worst_acc, e.avg_loss
        );
    }
    println!(
        "\nfinal accuracy {:.3} under a sign-flip attack; \
         {} model-pulls per round ({} total)",
        history.final_avg_accuracy(),
        history.messages_per_round,
        history.total_messages
    );
    Ok(())
}
