//! The "async jungle" experiment: which robust aggregators survive
//! degraded, asynchronous participation?
//!
//! *Collaborative Learning in the Jungle* is the reference point for
//! Byzantine robustness under asynchrony; this driver puts RPEL's rule
//! panel in that regime. Every run rides the deterministic virtual
//! clock (`util/vclock.rs`): two-point stragglers plus crash/rejoin
//! churn, rounds closed at a quorum of honest arrivals, missed
//! snapshots carried under bounded staleness. The sweep crosses
//! aggregation rules with the staleness bound (0 = a missed node is
//! served its own last commit; larger bounds carry its last published
//! half-step) and reports final accuracy next to the participation and
//! staleness ledgers.
//!
//! Emits `BENCH_async.json` (the `sweep` section; the `timing` section
//! belongs to `cargo bench --bench bench_async`).
//!
//! Run:  cargo run --release --example async_jungle

use rpel::aggregation::RuleKind;
use rpel::attacks::AttackKind;
use rpel::config::{ExperimentConfig, RuleChoice, Topology};
use rpel::coordinator::Trainer;
use rpel::data::TaskKind;
use rpel::metrics::History;
use rpel::testkit::scenario::Scenario;
use rpel::util::json::Json;
use std::collections::BTreeMap;

const ROUNDS: usize = 20;

fn jungle_cfg(rule: RuleKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(TaskKind::Tiny);
    cfg.name = format!("jungle_{rule:?}");
    cfg.n = 12;
    cfg.b = 2;
    cfg.topology = Topology::Epidemic { s: 6 };
    cfg.bhat = Some(2);
    cfg.attack = AttackKind::Alie;
    cfg.rule = RuleChoice::Epidemic(rule);
    cfg.rounds = ROUNDS;
    cfg.batch = 8;
    cfg.samples_per_node = 48;
    cfg.test_samples = 96;
    cfg.eval_every = 10;
    cfg
}

/// The jungle: the named straggler scenario plus crash/rejoin churn.
fn into_jungle(cfg: &mut ExperimentConfig, max_staleness: usize) {
    Scenario::named("straggler_twopoint")
        .expect("built-in scenario")
        .apply(cfg)
        .expect("scenario applies");
    cfg.asyn.max_staleness = max_staleness;
    cfg.asyn.crash_prob = 0.1;
    cfg.asyn.down_rounds = 2;
    cfg.validate().expect("jungle config validates");
}

struct Cell {
    rule: &'static str,
    mode: String,
    acc: f64,
    mean_participation: f64,
    stale_serves: u64,
    dropped_serves: u64,
}

fn run_cell(rule_name: &'static str, mode: String, cfg: &ExperimentConfig) -> anyhow::Result<Cell> {
    let hist: History = Trainer::from_config(cfg)?.run()?;
    let h = (cfg.n - cfg.b) as f64;
    let (mean_p, stale, dropped) = if cfg.asyn.is_enabled() {
        let sum: u64 = hist.participation_per_round.iter().map(|&p| p as u64).sum();
        let cap = cfg.asyn.max_staleness + 1;
        let stale: u64 = hist.staleness_hist[1..cap].iter().sum();
        (sum as f64 / ROUNDS as f64, stale, hist.staleness_hist[cap])
    } else {
        (h, 0, 0)
    };
    Ok(Cell {
        rule: rule_name,
        mode,
        acc: hist.final_avg_accuracy(),
        mean_participation: mean_p,
        stale_serves: stale,
        dropped_serves: dropped,
    })
}

fn main() -> anyhow::Result<()> {
    let rules = [
        ("mean", RuleKind::Mean),
        ("cwmed", RuleKind::CwMed),
        ("cwtm", RuleKind::CwTm),
        ("nnm_cwtm", RuleKind::NnmCwtm),
    ];
    println!(
        "async jungle: n=12 b=2 s=6 alie, two-point stragglers (quorum 7) \
         + crash/rejoin churn, {ROUNDS} rounds\n"
    );

    let mut cells = Vec::new();
    for (name, rule) in rules {
        cells.push(run_cell(name, "sync".into(), &jungle_cfg(rule))?);
        for ms in [0usize, 1, 3] {
            let mut cfg = jungle_cfg(rule);
            into_jungle(&mut cfg, ms);
            cells.push(run_cell(name, format!("async_ms{ms}"), &cfg)?);
        }
    }

    println!(
        "{:<10} {:<10} {:>8} {:>14} {:>12} {:>12}",
        "rule", "mode", "acc", "mean particip", "stale", "dropped"
    );
    for c in &cells {
        println!(
            "{:<10} {:<10} {:>8.3} {:>14.2} {:>12} {:>12}",
            c.rule, c.mode, c.acc, c.mean_participation, c.stale_serves, c.dropped_serves
        );
    }

    // the jungle headline: the paper's rule vs the non-robust baseline
    // under the harshest staleness bound
    let pick = |rule: &str, mode: &str| {
        cells
            .iter()
            .find(|c| c.rule == rule && c.mode == mode)
            .map(|c| c.acc)
            .unwrap_or(f64::NAN)
    };
    println!(
        "\nheadline: at max_staleness=3, nnm_cwtm holds {:.3} vs mean {:.3} \
         (sync nnm_cwtm reference {:.3})",
        pick("nnm_cwtm", "async_ms3"),
        pick("mean", "async_ms3"),
        pick("nnm_cwtm", "sync"),
    );

    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    root.insert("bench".into(), Json::Str("bench_async".into()));
    root.insert("produced_by".into(), Json::Str("examples/async_jungle".into()));
    root.insert("units".into(), Json::Str("ns_per_round".into()));
    root.insert("smoke".into(), Json::Null);
    root.insert("timing".into(), Json::Null); // bench_async fills this
    let sweep: Vec<Json> = cells
        .iter()
        .map(|c| {
            let mut obj = BTreeMap::new();
            obj.insert("rule".into(), Json::Str(c.rule.into()));
            obj.insert("mode".into(), Json::Str(c.mode.clone()));
            obj.insert("final_acc".into(), Json::Num(c.acc));
            obj.insert(
                "mean_participation".into(),
                Json::Num(c.mean_participation),
            );
            obj.insert("stale_serves".into(), Json::Num(c.stale_serves as f64));
            obj.insert("dropped_serves".into(), Json::Num(c.dropped_serves as f64));
            Json::Obj(obj)
        })
        .collect();
    root.insert("sweep".into(), Json::Arr(sweep));
    match std::fs::write("BENCH_async.json", Json::Obj(root).to_string_compact()) {
        Ok(()) => println!("\nwrote BENCH_async.json"),
        Err(e) => println!("\ncould not write BENCH_async.json: {e}"),
    }
    Ok(())
}
