//! Figures 4–7 driver: RPEL vs fixed-graph robust baselines (CS+,
//! ClippedGossip, GTS) at an **identical communication budget** — the
//! paper's key comparison. For each fan-in s, the baselines run on a
//! random connected graph with K = n·s/2 edges while RPEL pulls s random
//! peers; reports both average and worst-client accuracy under ALIE or
//! Dissensus.
//!
//! Run:  cargo run --release --example fixed_graph_comparison [-- --attack alie|dissensus]

use rpel::cli::Args;
use rpel::config::presets::{self, Scale};
use rpel::experiments;
use rpel::metrics::write_histories;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let attack = args.get_or("attack", "alie");
    let fig_id = match attack {
        "alie" => "fig4",
        "dissensus" => "fig6",
        other => anyhow::bail!("--attack must be alie|dissensus, got {other}"),
    };
    let fig = presets::figure(fig_id).unwrap();
    println!(
        "reproducing {}/{} (avg + worst client) — attack: {attack}",
        fig.id,
        if fig_id == "fig4" { "fig5" } else { "fig7" }
    );
    println!("expectation: {}\n", fig.expectation);

    let presets::FigureSeries::Training(cfgs) = fig.series(Scale::Tiny) else {
        unreachable!()
    };
    let mut histories = Vec::new();
    for cfg in &cfgs {
        histories.push(experiments::run_training(cfg)?);
    }

    // group by s: the budget-matched comparison table
    println!("\n=== budget-matched comparison (final avg / worst accuracy) ===");
    println!(
        "{:<8} {:>14} {:>18} {:>14} {:>12}",
        "s", "rpel", "cs_plus", "clipped", "gts"
    );
    for chunk in histories.chunks(4) {
        let s_label = chunk[0]
            .name
            .rsplit("/s")
            .next()
            .unwrap_or("?")
            .to_string();
        let fmt = |h: &rpel::metrics::History| {
            format!("{:.2}/{:.2}", h.final_avg_accuracy(), h.final_worst_accuracy())
        };
        println!(
            "{:<8} {:>14} {:>18} {:>14} {:>12}",
            s_label,
            fmt(&chunk[0]),
            fmt(&chunk[1]),
            fmt(&chunk[2]),
            fmt(&chunk[3])
        );
    }
    println!(
        "\npaper shape check: RPEL's worst-client column should dominate, \
         with the largest margin at the smallest s (sparse regime)."
    );
    let paths = write_histories("results/fixed_graph_comparison", &histories)?;
    println!("csv written under results/fixed_graph_comparison ({} files)", paths.len());
    Ok(())
}
