//! Figure 3 driver: the Effective-adversarial-fraction scalability study
//! (paper §6.3) — pure hypergeometric simulation at the paper's full
//! scale, up to n = 100 000 nodes with 10 000 Byzantine.
//!
//! Demonstrates the headline scaling law: at a fixed Byzantine fraction,
//! the fan-in s needed for an honest majority per pull grows only
//! logarithmically in n (Lemma 4.1) — 30 neighbors suffice at n = 100k.
//!
//! Run:  cargo run --release --example scalability_eaf

use rpel::config::presets::{self, Scale};
use rpel::experiments;
use rpel::sampling::selector::{lemma41_min_s, select_bhat_exact};

fn main() -> anyhow::Result<()> {
    let fig = presets::figure("fig3").unwrap();
    println!("reproducing {} — {}", fig.id, fig.title);
    println!("expectation: {}\n", fig.expectation);

    let presets::FigureSeries::Eaf(scenarios) = fig.series(Scale::Paper) else {
        unreachable!()
    };
    let rows = experiments::run_eaf(&scenarios, 2025);

    println!("\n=== Algorithm 2 (simulated, 5 runs) vs exact max-quantile ===");
    println!(
        "{:<24} {:>6} {:>8} {:>10} {:>12}",
        "scenario", "s", "b̂ sim", "b̂ exact", "EAF"
    );
    for r in &rows {
        let exact = select_bhat_exact(r.n, r.b, 200, r.s, 0.99);
        println!(
            "{:<24} {:>6} {:>8} {:>10} {:>12.3}",
            r.label, r.s, r.bhat, exact, r.eaf
        );
    }

    println!("\n=== Lemma 4.1 sufficient (log-scaling) bound, p = 0.99 ===");
    for (n, b) in [(100u64, 10u64), (10_000, 1_000), (100_000, 10_000)] {
        let s = lemma41_min_s(n, b, 200, 0.99);
        println!("n={n:<7} b={b:<6} (10%): Lemma 4.1 needs s >= {s}");
    }

    // the §6.3 headline claim, checked numerically
    let headline = rows
        .iter()
        .find(|r| r.n == 100_000 && r.s == 30)
        .expect("fig3 grid includes s=30 at n=100k");
    println!(
        "\nheadline (§6.3): n=100000, b=10000, s=30 → max selected attackers \
         b̂={} of 31 (EAF {:.3}) — honest majority per pull for all 80k honest \
         nodes across T=200 rounds: {}",
        headline.bhat,
        headline.eaf,
        if headline.eaf < 0.5 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}
