"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.nnm_cwtm import (
    mix_trim_pallas,
    nnm_cwtm_pallas,
    nnm_weights_from_dist,
    pairwise_sqdist_pallas,
)


def rand(m, d, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(scale=scale, size=(m, d)).astype(np.float32))


# ---------------------------------------------------------------------------
# pairwise_sqdist
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,d", [(2, 1), (3, 7), (8, 128), (16, 1000), (5, 4097)])
def test_sqdist_matches_ref(m, d):
    x = rand(m, d, seed=m * 1000 + d)
    got = pairwise_sqdist_pallas(x, tile_d=256)
    want = ref.pairwise_sqdist(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_sqdist_diagonal_zero():
    x = rand(6, 100, seed=3)
    d = np.asarray(pairwise_sqdist_pallas(x))
    np.testing.assert_allclose(np.diag(d), np.zeros(6), atol=1e-6)


def test_sqdist_symmetry():
    x = rand(9, 257, seed=4)
    d = np.asarray(pairwise_sqdist_pallas(x, tile_d=64))
    np.testing.assert_allclose(d, d.T, rtol=1e-6, atol=1e-6)


def test_sqdist_identical_rows():
    x = jnp.ones((4, 50), jnp.float32)
    d = np.asarray(pairwise_sqdist_pallas(x))
    np.testing.assert_allclose(d, np.zeros((4, 4)), atol=1e-7)


def test_sqdist_tile_larger_than_d():
    x = rand(5, 10, seed=5)
    got = pairwise_sqdist_pallas(x, tile_d=4096)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.pairwise_sqdist(x)), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# mix_trim
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,d,b", [(5, 17, 1), (7, 300, 3), (16, 2049, 7), (3, 1, 1)])
def test_mix_trim_matches_ref(m, d, b):
    x = rand(m, d, seed=m + d + b)
    w = ref.nnm_weights(x, b)
    got = mix_trim_pallas(w, x, b, tile_d=128)
    want = ref.cwtm(np.asarray(w) @ np.asarray(x), b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_mix_trim_b0_is_mean_of_mixed():
    x = rand(6, 40, seed=9)
    w = jnp.eye(6, dtype=jnp.float32)
    got = mix_trim_pallas(w, x, 0)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(jnp.mean(x, axis=0)), rtol=1e-6, atol=1e-6
    )


def test_mix_trim_rejects_overtrim():
    x = rand(4, 8)
    w = jnp.eye(4, dtype=jnp.float32)
    with pytest.raises(ValueError):
        mix_trim_pallas(w, x, 2)


# ---------------------------------------------------------------------------
# full rule
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,d,b",
    [(4, 10, 1), (7, 64, 2), (7, 64, 3), (16, 500, 7), (16, 500, 4),
     (20, 123, 3), (8, 4096, 2), (3, 2, 1), (12, 77, 0)],
)
def test_nnm_cwtm_matches_ref(m, d, b):
    x = rand(m, d, seed=m * 31 + d * 7 + b, scale=3.0)
    got = nnm_cwtm_pallas(x, b, tile_d=256)
    want = ref.nnm_cwtm(x, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_nnm_cwtm_agreement_on_identical_inputs():
    """R(x, x, ..., x) = x — unanimity (robustness sanity)."""
    x0 = rand(1, 200, seed=42)
    x = jnp.tile(x0, (9, 1))
    got = nnm_cwtm_pallas(x, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x0[0]), rtol=1e-5, atol=1e-6)


def test_nnm_cwtm_permutation_invariant():
    x = rand(10, 90, seed=17)
    perm = np.random.default_rng(0).permutation(10)
    a = np.asarray(nnm_cwtm_pallas(x, 3))
    b = np.asarray(nnm_cwtm_pallas(x[perm], 3))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_nnm_cwtm_outlier_resistance():
    """b Byzantine rows at huge magnitude must not drag the output away
    from the honest cluster — the qualitative robustness property."""
    rng = np.random.default_rng(5)
    honest = rng.normal(size=(12, 60)).astype(np.float32)
    byz = np.full((4, 60), 1e6, np.float32)
    x = jnp.asarray(np.concatenate([honest, byz]))
    out = np.asarray(nnm_cwtm_pallas(x, 4))
    hmean = honest.mean(axis=0)
    assert np.linalg.norm(out - hmean) < 5 * np.linalg.norm(honest.std(axis=0))


def test_nnm_weights_row_stochastic():
    x = rand(11, 30, seed=23)
    d = ref.pairwise_sqdist(x)
    w = np.asarray(nnm_weights_from_dist(d, 4))
    np.testing.assert_allclose(w.sum(axis=1), np.ones(11), rtol=1e-6)
    assert (w >= 0).all()
    # self is always the nearest neighbor -> diagonal is selected
    assert (np.diag(w) > 0).all()


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes, magnitudes, tile sizes
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=3, max_value=24),
    d=st.integers(min_value=1, max_value=600),
    frac=st.floats(min_value=0.0, max_value=0.49),
    tile=st.sampled_from([32, 128, 1024]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_nnm_cwtm(m, d, frac, tile, seed):
    b = min(int(frac * m), (m - 1) // 2)
    x = rand(m, d, seed=seed, scale=10.0)
    got = nnm_cwtm_pallas(x, b, tile_d=tile)
    want = ref.nnm_cwtm(x, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=2, max_value=32),
    d=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_sqdist(m, d, seed):
    x = rand(m, d, seed=seed, scale=5.0)
    got = pairwise_sqdist_pallas(x, tile_d=128)
    want = ref.pairwise_sqdist(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
