"""AOT pipeline tests: HLO text emission, manifest integrity, fixtures."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_lower_emits_hlo_text():
    spec = M.SPECS["mlp_tiny"]
    txt = aot.lower(M.make_eval_fn(spec),
                    aot.f32(M.param_count(spec)),
                    aot.f32(4, *spec.input_shape), aot.i32(4))
    assert "HloModule" in txt
    assert "ENTRY" in txt


def test_lower_aggregate_contains_sort():
    # The CWTM trim lowers to an XLA sort over the replica axis.
    txt = aot.lower(M.make_aggregate_fn(1), aot.f32(5, 32))
    assert "sort" in txt.lower()


def test_train_graph_is_pure_hlo():
    """No custom-calls in the train step (Pallas interpret / plain jnp only
    lower to standard HLO the CPU PJRT client can execute)."""
    spec = M.SPECS["mlp_tiny"]
    d = M.param_count(spec)
    txt = aot.lower(M.make_train_step_fn(spec),
                    aot.f32(d), aot.f32(d), aot.f32(8, *spec.input_shape),
                    aot.i32(8), aot.f32(), aot.f32(), aot.f32())
    assert "custom-call" not in txt


def test_aggregate_graph_is_pure_hlo():
    txt = aot.lower(M.make_aggregate_fn(2), aot.f32(8, 64))
    assert "custom-call" not in txt


def test_plan_scales():
    tiny_models, tiny_aggs = aot.plan("tiny")
    paper_models, _ = aot.plan("paper")
    all_models, _ = aot.plan("all")
    assert {m[0] for m in tiny_models} == {
        "mlp_tiny", "mlp_mnistlike", "mlp_cifarlike", "mlp_femnistlike"}
    assert {m[0] for m in paper_models} == {"mnist_cnn", "cifar_cnn", "femnist_cnn"}
    assert len(all_models) == len(tiny_models) + len(paper_models)
    for combos in tiny_aggs.values():
        for m, b in combos:
            assert m - 2 * b >= 1, "CWTM must keep at least one row"


def test_agg_fixtures_consistency():
    fx = aot.agg_fixtures()
    assert len(fx["cases"]) >= 8
    for case in fx["cases"]:
        m, d = case["m"], case["d"]
        assert len(case["x"]) == m * d
        assert len(case["mean"]) == d
        x = np.asarray(case["x"], np.float32).reshape(m, d)
        np.testing.assert_allclose(
            np.asarray(case["mean"]), x.mean(axis=0), rtol=1e-5, atol=1e-5
        )
        if "nnm_cwtm" in case:
            assert len(case["nnm_cwtm"]) == d
            assert len(case["nnm"]) == m * d


def test_model_fixtures_consistency():
    fx = aot.model_fixtures()
    for case in fx["cases"]:
        assert len(case["params"]) == case["d"]
        assert len(case["logp"]) == case["n"] * case["classes"]
        rows = np.asarray(case["logp"], np.float32).reshape(case["n"], -1)
        np.testing.assert_allclose(np.exp(rows).sum(axis=1),
                                   np.ones(case["n"]), rtol=1e-4)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_manifest_is_complete():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    kinds = {"init": 0, "train": 0, "eval": 0, "aggregate": 0}
    for art in manifest["artifacts"]:
        kinds[art["kind"]] += 1
        path = os.path.join(root, art["file"])
        assert os.path.exists(path), art["file"]
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head
    assert all(v > 0 for v in kinds.values()), kinds
