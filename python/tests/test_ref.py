"""Oracle self-consistency: the jnp reference rules satisfy the paper's
algebraic properties (Definition 5.1 territory) on their own."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def rand(m, d, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(scale=scale, size=(m, d)).astype(np.float32))


def test_cwtm_is_mean_when_b0():
    x = rand(7, 13, seed=1)
    np.testing.assert_allclose(
        np.asarray(ref.cwtm(x, 0)), np.asarray(ref.mean(x)), rtol=1e-6, atol=1e-6
    )


def test_cwtm_ignores_extremes():
    x = jnp.asarray(
        np.array([[0.0], [1.0], [2.0], [1e9], [-1e9]], dtype=np.float32)
    )
    out = np.asarray(ref.cwtm(x, 1))
    # sorted: -1e9, 0, 1, 2, 1e9 -> trim 1 -> mean(0,1,2) = 1
    np.testing.assert_allclose(out, [1.0], atol=1e-6)


def test_cwmed_odd_is_middle():
    x = jnp.asarray(np.array([[3.0], [1.0], [2.0]], dtype=np.float32))
    np.testing.assert_allclose(np.asarray(ref.cwmed(x)), [2.0])


def test_nnm_preserves_unanimity():
    x0 = rand(1, 20, seed=2)
    x = jnp.tile(x0, (6, 1))
    out = np.asarray(ref.nnm(x, 2))
    np.testing.assert_allclose(out, np.tile(np.asarray(x0), (6, 1)), rtol=1e-6)


def test_nnm_rows_are_convex_combinations():
    x = rand(9, 15, seed=3, scale=4.0)
    out = np.asarray(ref.nnm(x, 3))
    xs = np.asarray(x)
    lo, hi = xs.min(axis=0), xs.max(axis=0)
    assert (out >= lo - 1e-5).all() and (out <= hi + 1e-5).all()


def test_krum_returns_an_input():
    x = rand(8, 10, seed=4)
    out = np.asarray(ref.krum(x, 2))
    assert any(np.allclose(out, row) for row in np.asarray(x))


def test_krum_rejects_outlier():
    rng = np.random.default_rng(6)
    honest = rng.normal(size=(7, 5)).astype(np.float32)
    byz = np.full((1, 5), 100.0, np.float32)
    x = jnp.asarray(np.concatenate([honest, byz]))
    out = np.asarray(ref.krum(x, 1))
    assert not np.allclose(out, byz[0])


def test_geometric_median_translation_equivariance():
    x = rand(6, 8, seed=7)
    shift = np.float32(3.5)
    a = np.asarray(ref.geometric_median(x + shift))
    b = np.asarray(ref.geometric_median(x)) + shift
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_geometric_median_collinear():
    # gm of {0, 0, 0, 10} on a line is ~0 (majority point)
    x = jnp.asarray(np.array([[0.0], [0.0], [0.0], [10.0]], dtype=np.float32))
    out = np.asarray(ref.geometric_median(x))
    assert abs(out[0]) < 0.5


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(min_value=3, max_value=20),
    d=st.integers(min_value=1, max_value=50),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_robustness_bound_cwtm(m, d, seed):
    """Empirical check of Definition 5.1 for CWTM∘NNM with honest-only
    inputs: the output must stay within the honest spread.

    With U = all inputs (b=0 adversaries actually present), the bound
    reduces to ||R(v) - v̄||² ≤ κ/m · Σ||v_i - v̄||² with κ = O(b/(m)).
    We verify the conservative version κ <= 1 (any sane rule)."""
    b = (m - 1) // 3
    if m - 2 * b < 1:
        b = 0
    x = rand(m, d, seed=seed, scale=2.0)
    out = np.asarray(ref.nnm_cwtm(x, b))
    xs = np.asarray(x)
    vbar = xs.mean(axis=0)
    var = ((xs - vbar) ** 2).sum(axis=1).mean()
    err = ((out - vbar) ** 2).sum()
    assert err <= var + 1e-4


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=4, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_cwtm_between_min_max(m, seed):
    x = rand(m, 12, seed=seed, scale=6.0)
    b = (m - 1) // 2
    out = np.asarray(ref.cwtm(x, b))
    xs = np.asarray(x)
    assert (out >= xs.min(axis=0) - 1e-6).all()
    assert (out <= xs.max(axis=0) + 1e-6).all()
