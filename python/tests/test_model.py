"""L2 model graph tests: shapes, gradient correctness, training dynamics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


def batch_for(spec, n, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, *spec.input_shape)).astype(np.float32)
    y = rng.integers(0, spec.classes, size=(n,)).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("arch", ["mlp_tiny", "mlp_mnistlike", "mlp_cifarlike",
                                  "mlp_femnistlike"])
def test_param_count_positive_and_stable(arch):
    spec = M.SPECS[arch]
    d1 = M.param_count(spec)
    d2 = M.param_count(spec)
    assert d1 == d2 > 0


def test_init_deterministic_per_seed():
    spec = M.SPECS["mlp_tiny"]
    f = M.make_init_fn(spec)
    (a,) = f(jnp.int32(3))
    (b,) = f(jnp.int32(3))
    (c,) = f(jnp.int32(4))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.allclose(np.asarray(a), np.asarray(c))


@pytest.mark.parametrize("arch", ["mlp_tiny", "mlp_mnistlike"])
def test_forward_shapes_and_logprobs(arch):
    spec = M.SPECS[arch]
    (params,) = M.make_init_fn(spec)(jnp.int32(0))
    x, _ = batch_for(spec, 5)
    logp = M.forward(spec, params, x)
    assert logp.shape == (5, spec.classes)
    # rows are log-probabilities: exp sums to 1
    np.testing.assert_allclose(
        np.exp(np.asarray(logp)).sum(axis=1), np.ones(5), rtol=1e-5
    )


def test_grad_matches_finite_difference():
    spec = M.SPECS["mlp_tiny"]
    (params,) = M.make_init_fn(spec)(jnp.int32(1))
    x, y = batch_for(spec, 6, seed=1)
    wd = jnp.float32(1e-3)
    loss_fn = lambda p: M.nll_loss(spec, p, x, y, wd)
    g = jax.grad(loss_fn)(params)
    rng = np.random.default_rng(0)
    p64 = np.asarray(params, dtype=np.float64)
    for idx in rng.integers(0, p64.shape[0], size=8):
        eps = 1e-3
        ep = np.zeros_like(p64)
        ep[idx] = eps
        f1 = float(loss_fn(jnp.asarray((p64 + ep).astype(np.float32))))
        f0 = float(loss_fn(jnp.asarray((p64 - ep).astype(np.float32))))
        fd = (f1 - f0) / (2 * eps)
        assert abs(fd - float(g[idx])) < 5e-2, (idx, fd, float(g[idx]))


def test_train_step_decreases_loss_over_steps():
    spec = M.SPECS["mlp_tiny"]
    (params,) = M.make_init_fn(spec)(jnp.int32(0))
    momentum = jnp.zeros_like(params)
    step = jax.jit(M.make_train_step_fn(spec))
    x, y = batch_for(spec, 32, seed=2)
    first = None
    for _ in range(60):
        params, momentum, loss = step(
            params, momentum, x, y,
            jnp.float32(0.2), jnp.float32(0.9), jnp.float32(0.0),
        )
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.7, (first, float(loss))


def test_train_step_momentum_semantics():
    """m1 = (1-beta) * g when m0 = 0; x' = x - lr*m1."""
    spec = M.SPECS["mlp_tiny"]
    (params,) = M.make_init_fn(spec)(jnp.int32(0))
    x, y = batch_for(spec, 4, seed=3)
    wd = jnp.float32(0.0)
    beta = jnp.float32(0.9)
    lr = jnp.float32(0.1)
    g = jax.grad(lambda p: M.nll_loss(spec, p, x, y, wd))(params)
    step = M.make_train_step_fn(spec)
    p1, m1, _ = step(params, jnp.zeros_like(params), x, y, lr, beta, wd)
    np.testing.assert_allclose(
        np.asarray(m1), (1 - 0.9) * np.asarray(g), rtol=1e-5, atol=1e-7
    )
    np.testing.assert_allclose(
        np.asarray(p1), np.asarray(params) - 0.1 * np.asarray(m1),
        rtol=1e-5, atol=1e-7,
    )


def test_local_steps_scan_equals_manual_loop():
    spec = M.SPECS["mlp_tiny"]
    (params,) = M.make_init_fn(spec)(jnp.int32(5))
    m0 = jnp.zeros_like(params)
    k, bsz = 3, 8
    rng = np.random.default_rng(7)
    xs = jnp.asarray(rng.normal(size=(k, bsz, *spec.input_shape)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, spec.classes, size=(k, bsz)).astype(np.int32))
    lr, beta, wd = jnp.float32(0.1), jnp.float32(0.9), jnp.float32(1e-4)

    pk, mk, _ = M.make_train_step_fn(spec, local_steps=k)(params, m0, xs, ys, lr, beta, wd)

    p, m = params, m0
    step1 = M.make_train_step_fn(spec, local_steps=1)
    for i in range(k):
        p, m, _ = step1(p, m, xs[i], ys[i], lr, beta, wd)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(p), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(mk), np.asarray(m), rtol=1e-5, atol=1e-6)


def test_eval_fn_counts():
    spec = M.SPECS["mlp_tiny"]
    (params,) = M.make_init_fn(spec)(jnp.int32(0))
    x, y = batch_for(spec, 50, seed=4)
    correct, loss_sum = M.make_eval_fn(spec)(params, x, y)
    logp = M.forward(spec, params, x)
    pred = np.asarray(jnp.argmax(logp, axis=-1))
    assert float(correct) == float((pred == np.asarray(y)).sum())
    assert float(loss_sum) > 0


def test_weight_decay_pulls_toward_zero():
    spec = M.SPECS["mlp_tiny"]
    (params,) = M.make_init_fn(spec)(jnp.int32(0))
    x, y = batch_for(spec, 8, seed=5)
    step = M.make_train_step_fn(spec)
    _, m_nowd, _ = step(params, jnp.zeros_like(params), x, y,
                        jnp.float32(0.1), jnp.float32(0.0), jnp.float32(0.0))
    _, m_wd, _ = step(params, jnp.zeros_like(params), x, y,
                      jnp.float32(0.1), jnp.float32(0.0), jnp.float32(1.0))
    # with beta=0 the momentum equals the gradient; wd adds wd*params
    np.testing.assert_allclose(
        np.asarray(m_wd) - np.asarray(m_nowd), np.asarray(params),
        rtol=1e-3, atol=1e-5,
    )


@pytest.mark.parametrize("arch", ["mnist_cnn", "cifar_cnn", "femnist_cnn"])
def test_paper_cnn_forward_shapes(arch):
    """Paper architectures trace correctly (param counts match the compact
    notation) even though tiny-scale artifact builds skip them."""
    spec = M.SPECS[arch]
    d = M.param_count(spec)
    assert d > 10_000
    (params,) = M.make_init_fn(spec)(jnp.int32(0))
    assert params.shape == (d,)
    x, _ = batch_for(spec, 2)
    logp = M.forward(spec, params, x)
    assert logp.shape == (2, spec.classes)


def test_mnist_cnn_param_count_exact():
    # C(20,5x5): 1*20*25+20=520; C(20,5x5): 20*20*25+20=10020
    # after convs+pools: 28->24->12->8->4 => 4*4*20=320
    # L(500): 320*500+500=160500 ; L(10): 500*10+10=5010 ; total 176050
    assert M.param_count(M.SPECS["mnist_cnn"]) == 176050
