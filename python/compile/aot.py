"""AOT compiler: lower every Layer-2 graph to HLO text + manifest.

Runs ONCE at build time (``make artifacts``); the Rust coordinator is
self-contained afterwards.  Emits into ``artifacts/``:

  * ``<name>.hlo.txt``      — one HLO-text module per (graph, static shape)
  * ``manifest.json``       — index the Rust runtime loads (name, kind,
                              shapes, arch metadata)
  * ``fixtures/*.json``     — oracle fixtures for Rust differential tests

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts \
            [--scale tiny|paper|all] [--force]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels import ref


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


# ---------------------------------------------------------------------------
# Artifact plan
# ---------------------------------------------------------------------------

# (arch, train_batch, eval_n, local_steps variants)
TINY_MODELS = [
    ("mlp_tiny", 8, 64, (1,)),
    ("mlp_mnistlike", 25, 512, (1,)),
    ("mlp_cifarlike", 50, 512, (1, 3)),
    ("mlp_femnistlike", 50, 512, (1, 3)),
]

PAPER_MODELS = [
    ("mnist_cnn", 25, 512, (1,)),
    ("cifar_cnn", 50, 512, (1, 3)),
    ("femnist_cnn", 50, 512, (1, 3)),
]

# aggregation variants per arch: list of (m = s+1, bhat)
TINY_AGG = {
    "mlp_tiny": [(8, 2)],
    "mlp_mnistlike": [(16, 4), (16, 5), (16, 6), (16, 7)],
    "mlp_cifarlike": [(7, 0), (7, 1), (7, 2), (7, 3), (11, 2), (11, 3), (20, 2), (20, 3)],
    "mlp_femnistlike": [(7, 0), (7, 3)],
}

PAPER_AGG = {
    "mnist_cnn": [(16, 6), (16, 7)],
    "cifar_cnn": [(7, 3), (20, 3)],
    "femnist_cnn": [(7, 0), (7, 3)],
}


def plan(scale: str):
    models, aggs = [], {}
    if scale in ("tiny", "all"):
        models += TINY_MODELS
        aggs.update(TINY_AGG)
    if scale in ("paper", "all"):
        models += PAPER_MODELS
        aggs.update(PAPER_AGG)
    return models, aggs


# ---------------------------------------------------------------------------
# Emission
# ---------------------------------------------------------------------------


def emit(out_dir: str, name: str, text: str, entry: dict, manifest: list,
         force: bool) -> None:
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    entry = dict(entry, name=name, file=f"{name}.hlo.txt",
                 sha256=hashlib.sha256(text.encode()).hexdigest()[:16])
    manifest.append(entry)
    if not force and os.path.exists(path):
        with open(path) as f:
            if f.read() == text:
                print(f"  = {name} (unchanged)")
                return
    with open(path, "w") as f:
        f.write(text)
    print(f"  + {name} ({len(text)} chars)")


def build_models(out_dir: str, models, manifest: list, force: bool) -> None:
    for arch, batch, eval_n, ls_variants in models:
        spec = M.SPECS[arch]
        d = M.param_count(spec)
        ishape = list(spec.input_shape)
        base = dict(arch=arch, d=d, input_shape=ishape, classes=spec.classes)

        emit(out_dir, f"init_{arch}",
             lower(M.make_init_fn(spec), i32()),
             dict(base, kind="init"), manifest, force)

        for ls in ls_variants:
            if ls == 1:
                xs = f32(batch, *ishape)
                ys = i32(batch)
            else:
                xs = f32(ls, batch, *ishape)
                ys = i32(ls, batch)
            emit(out_dir, f"train_{arch}_b{batch}_k{ls}",
                 lower(M.make_train_step_fn(spec, ls),
                       f32(d), f32(d), xs, ys, f32(), f32(), f32()),
                 dict(base, kind="train", batch=batch, local_steps=ls),
                 manifest, force)

        emit(out_dir, f"eval_{arch}_n{eval_n}",
             lower(M.make_eval_fn(spec), f32(d), f32(eval_n, *ishape), i32(eval_n)),
             dict(base, kind="eval", eval_n=eval_n), manifest, force)


def build_aggregates(out_dir: str, models, aggs, manifest: list, force: bool) -> None:
    arch_d = {arch: M.param_count(M.SPECS[arch]) for arch, *_ in models}
    for arch, combos in aggs.items():
        if arch not in arch_d:
            continue
        d = arch_d[arch]
        for m, bhat in combos:
            emit(out_dir, f"aggregate_{arch}_m{m}_b{bhat}",
                 lower(M.make_aggregate_fn(bhat), f32(m, d)),
                 dict(kind="aggregate", arch=arch, d=d, m=m, bhat=bhat),
                 manifest, force)


# ---------------------------------------------------------------------------
# Fixtures (oracle outputs for Rust differential tests)
# ---------------------------------------------------------------------------


def agg_fixtures() -> dict:
    """Aggregation-rule fixtures: random inputs + jnp-oracle outputs."""
    rng = np.random.default_rng(2025)
    cases = []
    for (m, d, b) in [(5, 8, 1), (7, 16, 2), (7, 16, 3), (9, 33, 2),
                      (16, 24, 7), (16, 24, 5), (20, 12, 3), (4, 6, 1),
                      (8, 2048, 2), (3, 5, 1), (12, 40, 0)]:
        x = rng.normal(scale=2.0, size=(m, d)).astype(np.float32)
        xj = jnp.asarray(x)
        case = {
            "m": m, "d": d, "b": b,
            "x": [float(v) for v in x.reshape(-1)],
            "mean": [float(v) for v in np.asarray(ref.mean(xj)).reshape(-1)],
            "cwmed": [float(v) for v in np.asarray(ref.cwmed(xj)).reshape(-1)],
        }
        if m - 2 * b >= 1:
            case["cwtm"] = [float(v) for v in np.asarray(ref.cwtm(xj, b)).reshape(-1)]
            case["nnm"] = [float(v) for v in np.asarray(ref.nnm(xj, b)).reshape(-1)]
            case["nnm_cwtm"] = [float(v) for v in np.asarray(ref.nnm_cwtm(xj, b)).reshape(-1)]
        if m - b - 2 >= 1:
            case["krum"] = [float(v) for v in np.asarray(ref.krum(xj, b)).reshape(-1)]
        case["geomedian"] = [float(v) for v in np.asarray(ref.geometric_median(xj)).reshape(-1)]
        cases.append(case)
    return {"cases": cases}


def model_fixtures() -> dict:
    """Native-MLP cross-check fixtures (Rust model::native vs jnp)."""
    out = {"cases": []}
    for arch in ("mlp_tiny", "mlp_mnistlike"):
        spec = M.SPECS[arch]
        d = M.param_count(spec)
        (params,) = M.make_init_fn(spec)(jnp.int32(7))
        rng = np.random.default_rng(11)
        n = 4
        x = rng.normal(size=(n, *spec.input_shape)).astype(np.float32)
        y = rng.integers(0, spec.classes, size=(n,)).astype(np.int32)
        logp = M.forward(spec, params, jnp.asarray(x))
        correct, loss_sum = M.make_eval_fn(spec)(params, jnp.asarray(x), jnp.asarray(y))
        out["cases"].append({
            "arch": arch, "d": d, "n": n,
            "din": int(spec.input_shape[0]), "classes": spec.classes,
            "params": [float(v) for v in np.asarray(params).reshape(-1)],
            "x": [float(v) for v in x.reshape(-1)],
            "y": [int(v) for v in y],
            "logp": [float(v) for v in np.asarray(logp).reshape(-1)],
            "correct": float(correct), "loss_sum": float(loss_sum),
        })
    return out


# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "paper", "all"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "fixtures"), exist_ok=True)

    models, aggs = plan(args.scale)
    manifest: list[dict] = []

    print(f"[aot] lowering models ({args.scale}) -> {out_dir}")
    build_models(out_dir, models, manifest, args.force)
    print("[aot] lowering aggregation (Pallas NNM∘CWTM)")
    build_aggregates(out_dir, models, aggs, manifest, args.force)

    print("[aot] writing fixtures")
    with open(os.path.join(out_dir, "fixtures", "agg_fixtures.json"), "w") as f:
        json.dump(agg_fixtures(), f)
    with open(os.path.join(out_dir, "fixtures", "model_fixtures.json"), "w") as f:
        json.dump(model_fixtures(), f)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"version": 1, "scale": args.scale, "artifacts": manifest}, f, indent=1)
    print(f"[aot] manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    sys.exit(main())
