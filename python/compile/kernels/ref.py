"""Pure-jnp reference oracle for the aggregation hot path.

This module is the single source of truth for numerical correctness of:

  * the Pallas kernels in ``nnm_cwtm.py`` (pytest + hypothesis sweeps), and
  * the Rust-native aggregators (via JSON fixtures emitted by ``aot.py``).

Everything here follows the paper's definitions:

  * NNM (Nearest-Neighbor Mixing, Allouah et al. 2023): each input vector is
    replaced by the average of its ``m - b`` nearest neighbors (L2 distance,
    including itself).
  * CWTM (coordinate-wise trimmed mean, Yin et al. 2018): per coordinate,
    drop the ``b`` largest and ``b`` smallest values and average the rest.
  * The paper's aggregation rule R = CWTM ∘ NNM (Section 6.1), which is
    (s, b̂, κ)-robust with κ = O(b̂ / (s+1)) (Corollary 5.7 remark).

All functions take ``X`` of shape ``[m, d]`` where ``m = s + 1`` (the
pulling node's own half-step model first, then the ``s`` pulled models).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sqdist(x: jax.Array) -> jax.Array:
    """Squared L2 distance matrix, shape [m, m].

    Uses the explicit difference form (not the Gram trick) so it is exact
    for float32 inputs — the oracle must not lose precision to cancellation.
    """
    diff = x[:, None, :] - x[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def nnm_weights(x: jax.Array, b: int) -> jax.Array:
    """Row-stochastic mixing matrix W of the NNM pre-aggregation.

    ``W[i, j] = 1/k`` if j is among the ``k = m - b`` nearest neighbors of i
    (including i itself), else 0.  Ties are broken by index order (argsort is
    stable), which the Pallas path and the Rust path replicate.
    """
    m = x.shape[0]
    k = m - b
    if k < 1:
        raise ValueError(f"NNM needs m - b >= 1, got m={m}, b={b}")
    dist = pairwise_sqdist(x)
    order = jnp.argsort(dist, axis=1, stable=True)
    sel = order[:, :k]  # [m, k] neighbor indices
    w = jnp.zeros((m, m), dtype=x.dtype)
    rows = jnp.repeat(jnp.arange(m), k)
    w = w.at[rows, sel.reshape(-1)].set(1.0 / k)
    return w


def nnm(x: jax.Array, b: int) -> jax.Array:
    """Nearest-Neighbor Mixing: [m, d] -> [m, d]."""
    return nnm_weights(x, b) @ x


def cwtm(x: jax.Array, b: int) -> jax.Array:
    """Coordinate-wise trimmed mean: [m, d] -> [d].

    Sorts each coordinate across the m inputs, removes the b smallest and b
    largest, and averages the remaining m - 2b values.
    """
    m = x.shape[0]
    if m - 2 * b < 1:
        raise ValueError(f"CWTM needs m - 2b >= 1, got m={m}, b={b}")
    s = jnp.sort(x, axis=0)
    return jnp.mean(s[b : m - b, :], axis=0)


def cwmed(x: jax.Array) -> jax.Array:
    """Coordinate-wise median: [m, d] -> [d]."""
    return jnp.median(x, axis=0)


def nnm_cwtm(x: jax.Array, b: int) -> jax.Array:
    """The paper's aggregation rule R = CWTM_b ∘ NNM_b : [m, d] -> [d]."""
    return cwtm(nnm(x, b), b)


def krum(x: jax.Array, b: int) -> jax.Array:
    """Krum (Blanchard et al. 2017): returns the input with the smallest
    sum of squared distances to its m - b - 2 nearest neighbors (excluding
    itself)."""
    m = x.shape[0]
    k = m - b - 2
    if k < 1:
        raise ValueError(f"Krum needs m - b - 2 >= 1, got m={m}, b={b}")
    dist = pairwise_sqdist(x)
    # exclude self-distance by pushing the diagonal to +inf
    dist = dist + jnp.diag(jnp.full((m,), jnp.inf, dtype=x.dtype))
    nearest = jnp.sort(dist, axis=1)[:, :k]
    scores = jnp.sum(nearest, axis=1)
    return x[jnp.argmin(scores)]


def geometric_median(x: jax.Array, iters: int = 100, eps: float = 1e-8) -> jax.Array:
    """Geometric median via Weiszfeld iterations: [m, d] -> [d].

    Matches the Rust implementation: fixed iteration count, epsilon-guarded
    denominators, initialized at the coordinate mean.
    """

    def step(z, _):
        norms = jnp.sqrt(jnp.sum((x - z[None, :]) ** 2, axis=1))
        w = 1.0 / jnp.maximum(norms, eps)
        z_new = jnp.sum(w[:, None] * x, axis=0) / jnp.sum(w)
        return z_new, None

    z0 = jnp.mean(x, axis=0)
    z, _ = jax.lax.scan(step, z0, None, length=iters)
    return z


def mean(x: jax.Array) -> jax.Array:
    """Plain (non-robust) average — the gossip baseline."""
    return jnp.mean(x, axis=0)
