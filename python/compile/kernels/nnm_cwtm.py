"""Pallas kernels for the RPEL aggregation hot path: R = CWTM ∘ NNM.

Layer-1 of the stack.  Two kernels, both tiled over the model dimension
``d`` (the only large axis — ``m = s + 1`` is at most a few dozen):

  1. ``pairwise_sqdist_pallas`` — the [m, m] squared-distance matrix,
     accumulated tile-by-tile over ``d``.
  2. ``mix_trim_pallas`` — given the NNM row-stochastic mixing matrix W
     ([m, m], produced from the distance matrix by plain-jnp top-k logic
     that lowers into the same HLO), computes ``mixed = W @ X`` on each
     tile and immediately applies the coordinate-wise trimmed mean,
     writing a [d] output without materializing ``mixed`` in HBM.

TPU thinking (see DESIGN.md §Hardware-Adaptation): the tile size is chosen
so each block's working set (X tile [m, TILE_D] + W [m, m] + out [TILE_D])
stays well inside a 16 MiB VMEM budget; the ``W @ X`` contraction is an
(m×m)(m×TILE_D) matmul shaped for the MXU; the trim is a sort along the
small replica axis.  On this testbed the kernels are lowered with
``interpret=True`` (the CPU PJRT plugin cannot execute Mosaic custom
calls), which preserves the exact blocking structure and numerics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 2048 f32 lanes x m<=64 rows ≈ 512 KiB VMEM for the X tile — comfortably
# double-bufferable inside 16 MiB.  Multiple of 128 for TPU lane tiling.
DEFAULT_TILE_D = 2048


def _sqdist_kernel(x_ref, out_ref):
    """Accumulate partial pairwise squared distances for one d-tile."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]  # [m, tile_d]
    diff = x[:, None, :] - x[None, :, :]  # [m, m, tile_d]
    out_ref[...] += jnp.sum(diff * diff, axis=-1)


def _mix_trim_kernel(w_ref, x_ref, out_ref, *, b: int):
    """One d-tile of CWTM_b(W @ X): mix rows, sort the replica axis,
    trim b from each end, average."""
    mixed = jnp.dot(w_ref[...], x_ref[...])  # [m, tile_d] — MXU matmul
    m = mixed.shape[0]
    srt = jnp.sort(mixed, axis=0)
    out_ref[...] = jnp.mean(srt[b : m - b, :], axis=0)


def _pad_d(x: jax.Array, tile_d: int) -> tuple[jax.Array, int]:
    """Zero-pad the trailing axis of [m, d] to a multiple of tile_d."""
    d = x.shape[-1]
    dp = ((d + tile_d - 1) // tile_d) * tile_d
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
    return x, dp


def pairwise_sqdist_pallas(x: jax.Array, tile_d: int = DEFAULT_TILE_D) -> jax.Array:
    """[m, d] -> [m, m] squared L2 distances, tiled over d.

    Zero padding of the d axis is harmless: padded coordinates contribute
    zero to every pairwise difference.
    """
    m, d = x.shape
    tile_d = min(tile_d, max(d, 1))
    xp, dp = _pad_d(x, tile_d)
    grid = dp // tile_d
    return pl.pallas_call(
        _sqdist_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, m), x.dtype),
        interpret=True,
    )(xp)


def mix_trim_pallas(
    w: jax.Array, x: jax.Array, b: int, tile_d: int = DEFAULT_TILE_D
) -> jax.Array:
    """CWTM_b(W @ X): ([m, m], [m, d]) -> [d], tiled over d.

    The trimmed mean of each padded coordinate is computed on garbage zeros
    and sliced off afterwards, so padding never reaches the caller.
    """
    m, d = x.shape
    if m - 2 * b < 1:
        raise ValueError(f"CWTM needs m - 2b >= 1, got m={m}, b={b}")
    tile_d = min(tile_d, max(d, 1))
    xp, dp = _pad_d(x, tile_d)
    grid = dp // tile_d
    out = pl.pallas_call(
        functools.partial(_mix_trim_kernel, b=b),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, tile_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), x.dtype),
        interpret=True,
    )(w, xp)
    return out[:d]


def nnm_weights_from_dist(dist: jax.Array, b: int, dtype=jnp.float32) -> jax.Array:
    """Build the NNM row-stochastic mixing matrix from a distance matrix.

    Runs in plain jnp — the matrix is [m, m] (tiny) and top-k selection is
    control-flow-ish, so there is no benefit to a kernel.  Tie-breaking by
    index order matches ``ref.nnm_weights`` (stable argsort).
    """
    m = dist.shape[0]
    k = m - b
    if k < 1:
        raise ValueError(f"NNM needs m - b >= 1, got m={m}, b={b}")
    order = jnp.argsort(dist, axis=1, stable=True)
    sel = order[:, :k]
    w = jnp.zeros((m, m), dtype=dtype)
    rows = jnp.repeat(jnp.arange(m), k)
    return w.at[rows, sel.reshape(-1)].set(jnp.asarray(1.0 / k, dtype=dtype))


def nnm_cwtm_pallas(x: jax.Array, b: int, tile_d: int = DEFAULT_TILE_D) -> jax.Array:
    """The full aggregation rule R(X) = CWTM_b(NNM_b(X)) : [m, d] -> [d].

    This is the function ``aot.py`` lowers to HLO (one executable per
    static (m, d, b) triple); the Rust coordinator calls it every round
    for every honest node.
    """
    dist = pairwise_sqdist_pallas(x, tile_d=tile_d)
    w = nnm_weights_from_dist(dist, b, dtype=x.dtype)
    return mix_trim_pallas(w, x, b, tile_d=tile_d)
