"""Layer-2: per-node compute graphs in JAX (build-time only).

Defines the model zoo (paper CNN architectures + reduced MLPs for the
1-core testbed), the flat-parameter codec, and the three graphs that
``aot.py`` lowers to HLO text for the Rust coordinator:

  * ``init_fn``       : (seed i32[])                       -> params f32[d]
  * ``train_step_fn`` : (params, momentum, x, y, lr, beta, wd)
                        -> (params', momentum', loss)
  * ``eval_fn``       : (params, x, y) -> (correct f32[], loss_sum f32[])

``train_step_fn`` implements exactly Algorithm 1 lines 3–6:

    g  = ∇ℓ(x_i^t, ξ)  (+ weight decay)
    m  = β m + (1 − β) g
    x' = x − η m        (the half-step x^{t+1/2}; aggregation happens in
                         Rust / in the Pallas aggregation executable)

Interfaces use a single flat f32[d] parameter vector so the Rust side never
needs to know the pytree structure.  ``lr``, ``beta``, ``wd`` are runtime
scalars: LR schedules (the paper's CIFAR staircase) need no recompilation.

Paper architectures (Appendix C, Tables 1–2), compact notation:
  MNIST   : C(20)-R-M-C(20)-R-M-L(500)-R-L(10)-S          (5x5 convs)
  CIFAR-10: C(64)-R-B-C(64)-R-B-M-D-C(128)-R-B-C(128)-R-B-M-D-
            L(128)-R-D-L(10)-S                             (3x3 convs)
  FEMNIST : C(64)-R-M-C(128)-R-M-L(1024)-R-L(62)-S         (5x5 convs)

BatchNorm is replaced by static (non-learned) feature standardization and
dropout is omitted in the AOT graphs — both are stateful/stochastic pieces
that would force per-step RNG plumbing through the HLO interface; DESIGN.md
§Substitutions records this (the robustness phenomena under study do not
depend on them).
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


# ---------------------------------------------------------------------------
# Architecture specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Layer:
    kind: str  # "dense" | "conv" | "relu" | "maxpool" | "flatten" | "norm"
    out: int = 0  # dense units / conv channels
    ksize: int = 0  # conv kernel size


@dataclass(frozen=True)
class ModelSpec:
    """A model architecture plus its input geometry."""

    name: str
    input_shape: tuple[int, ...]  # per-example shape, e.g. (64,) or (28, 28, 1)
    classes: int
    layers: tuple[Layer, ...] = field(default_factory=tuple)

    @property
    def is_conv(self) -> bool:
        return len(self.input_shape) == 3


def _mlp(name: str, din: int, hidden: list[int], classes: int) -> ModelSpec:
    layers: list[Layer] = []
    for h in hidden:
        layers += [Layer("dense", out=h), Layer("relu")]
    layers += [Layer("dense", out=classes)]
    return ModelSpec(name, (din,), classes, tuple(layers))


def _conv(out: int, k: int) -> Layer:
    return Layer("conv", out=out, ksize=k)


SPECS: dict[str, ModelSpec] = {
    # --- reduced-scale models (default on the 1-core testbed) ------------
    "mlp_mnistlike": _mlp("mlp_mnistlike", 64, [64], 10),
    "mlp_cifarlike": _mlp("mlp_cifarlike", 96, [128, 64], 10),
    "mlp_femnistlike": _mlp("mlp_femnistlike", 64, [128], 62),
    # tiny model for quickstart/tests
    "mlp_tiny": _mlp("mlp_tiny", 16, [16], 4),
    # --- paper architectures ---------------------------------------------
    "mnist_cnn": ModelSpec(
        "mnist_cnn",
        (28, 28, 1),
        10,
        (
            _conv(20, 5), Layer("relu"), Layer("maxpool"),
            _conv(20, 5), Layer("relu"), Layer("maxpool"),
            Layer("flatten"),
            Layer("dense", out=500), Layer("relu"),
            Layer("dense", out=10),
        ),
    ),
    "cifar_cnn": ModelSpec(
        "cifar_cnn",
        (32, 32, 3),
        10,
        (
            _conv(64, 3), Layer("relu"), Layer("norm"),
            _conv(64, 3), Layer("relu"), Layer("norm"), Layer("maxpool"),
            _conv(128, 3), Layer("relu"), Layer("norm"),
            _conv(128, 3), Layer("relu"), Layer("norm"), Layer("maxpool"),
            Layer("flatten"),
            Layer("dense", out=128), Layer("relu"),
            Layer("dense", out=10),
        ),
    ),
    "femnist_cnn": ModelSpec(
        "femnist_cnn",
        (28, 28, 1),
        62,
        (
            _conv(64, 5), Layer("relu"), Layer("maxpool"),
            _conv(128, 5), Layer("relu"), Layer("maxpool"),
            Layer("flatten"),
            Layer("dense", out=1024), Layer("relu"),
            Layer("dense", out=62),
        ),
    ),
}


# ---------------------------------------------------------------------------
# Parameter construction / flat codec
# ---------------------------------------------------------------------------


def _conv_pad(spec: ModelSpec) -> str:
    # Paper: padding 1 for CIFAR 3x3 convs ("SAME"), padding 0 for the 5x5
    # MNIST/FEMNIST convs ("VALID").
    return "SAME" if spec.layers and any(l.kind == "conv" and l.ksize == 3 for l in spec.layers) else "VALID"


def init_pytree(spec: ModelSpec, key: jax.Array):
    """He-initialized parameter pytree (list of {'w','b'} dicts)."""
    params = []
    shape = spec.input_shape
    pad = _conv_pad(spec)
    for layer in spec.layers:
        if layer.kind == "dense":
            fan_in = math.prod(shape)
            key, sub = jax.random.split(key)
            w = jax.random.normal(sub, (fan_in, layer.out), jnp.float32)
            w = w * jnp.sqrt(2.0 / fan_in)
            params.append({"w": w, "b": jnp.zeros((layer.out,), jnp.float32)})
            shape = (layer.out,)
        elif layer.kind == "conv":
            h, w_, c = shape
            k = layer.ksize
            key, sub = jax.random.split(key)
            fan_in = k * k * c
            wt = jax.random.normal(sub, (k, k, c, layer.out), jnp.float32)
            wt = wt * jnp.sqrt(2.0 / fan_in)
            params.append({"w": wt, "b": jnp.zeros((layer.out,), jnp.float32)})
            if pad == "VALID":
                h, w_ = h - k + 1, w_ - k + 1
            shape = (h, w_, layer.out)
        elif layer.kind == "maxpool":
            h, w_, c = shape
            shape = (h // 2, w_ // 2, c)
        elif layer.kind == "flatten":
            shape = (math.prod(shape),)
        # relu / norm: no params, no shape change
    return params


def param_count(spec: ModelSpec) -> int:
    flat, _ = ravel_pytree(init_pytree(spec, jax.random.PRNGKey(0)))
    return int(flat.shape[0])


@functools.lru_cache(maxsize=None)
def _unravel_fn(name: str):
    spec = SPECS[name]
    flat, unravel = ravel_pytree(init_pytree(spec, jax.random.PRNGKey(0)))
    return int(flat.shape[0]), unravel


def forward(spec: ModelSpec, flat_params: jax.Array, x: jax.Array) -> jax.Array:
    """Log-softmax outputs, shape [B, classes]. x: [B, *input_shape]."""
    _, unravel = _unravel_fn(spec.name)
    params = unravel(flat_params)
    pad = _conv_pad(spec)
    idx = 0
    h = x
    for layer in spec.layers:
        if layer.kind == "dense":
            if h.ndim > 2:
                h = h.reshape(h.shape[0], -1)
            p = params[idx]
            idx += 1
            h = h @ p["w"] + p["b"]
        elif layer.kind == "conv":
            p = params[idx]
            idx += 1
            h = jax.lax.conv_general_dilated(
                h, p["w"], window_strides=(1, 1), padding=pad,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            ) + p["b"]
        elif layer.kind == "relu":
            h = jax.nn.relu(h)
        elif layer.kind == "maxpool":
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max,
                window_dimensions=(1, 2, 2, 1),
                window_strides=(1, 2, 2, 1),
                padding="VALID",
            )
        elif layer.kind == "flatten":
            h = h.reshape(h.shape[0], -1)
        elif layer.kind == "norm":
            # static standardization over spatial dims (BatchNorm stand-in)
            mu = jnp.mean(h, axis=(1, 2), keepdims=True)
            var = jnp.var(h, axis=(1, 2), keepdims=True)
            h = (h - mu) / jnp.sqrt(var + 1e-5)
    return jax.nn.log_softmax(h, axis=-1)


# ---------------------------------------------------------------------------
# AOT graphs
# ---------------------------------------------------------------------------


def nll_loss(spec: ModelSpec, flat_params: jax.Array, x: jax.Array, y: jax.Array,
             wd: jax.Array) -> jax.Array:
    """Mean NLL + L2 weight decay (the paper's 'weight L2 regularization')."""
    logp = forward(spec, flat_params, x)
    nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return nll + 0.5 * wd * jnp.sum(flat_params * flat_params)


def make_init_fn(spec: ModelSpec):
    def init_fn(seed: jax.Array):
        key = jax.random.PRNGKey(seed)
        flat, _ = ravel_pytree(init_pytree(spec, key))
        return (flat,)

    return init_fn


def make_train_step_fn(spec: ModelSpec, local_steps: int = 1):
    """Momentum-SGD half-step (Algorithm 1 lines 3–6).

    For ``local_steps > 1`` (paper §C.3), ``x``/``y`` carry a leading
    [local_steps] axis and the graph scans over them, matching "3 local
    steps at each iteration".
    """

    def one_step(carry, batch):
        params, momentum, lr, beta, wd = carry
        bx, by = batch
        loss, grad = jax.value_and_grad(
            lambda p: nll_loss(spec, p, bx, by, wd)
        )(params)
        momentum = beta * momentum + (1.0 - beta) * grad
        params = params - lr * momentum
        return (params, momentum, lr, beta, wd), loss

    if local_steps == 1:

        def train_step(params, momentum, x, y, lr, beta, wd):
            (params, momentum, *_), loss = one_step(
                (params, momentum, lr, beta, wd), (x, y)
            )
            return params, momentum, loss

    else:

        def train_step(params, momentum, x, y, lr, beta, wd):
            (params, momentum, *_), losses = jax.lax.scan(
                one_step, (params, momentum, lr, beta, wd), (x, y)
            )
            return params, momentum, jnp.mean(losses)

    return train_step


def make_eval_fn(spec: ModelSpec):
    """Returns (#correct, summed NLL) over the eval batch — Rust divides."""

    def eval_fn(params, x, y):
        logp = forward(spec, params, x)
        pred = jnp.argmax(logp, axis=-1)
        correct = jnp.sum((pred == y).astype(jnp.float32))
        loss_sum = -jnp.sum(jnp.take_along_axis(logp, y[:, None], axis=1))
        return correct, loss_sum

    return eval_fn


def make_aggregate_fn(b: int, tile_d: int | None = None):
    """The Pallas aggregation rule as an AOT graph: X [m, d] -> [d]."""
    from compile.kernels.nnm_cwtm import DEFAULT_TILE_D, nnm_cwtm_pallas

    td = tile_d or DEFAULT_TILE_D

    def aggregate(x):
        return (nnm_cwtm_pallas(x, b, tile_d=td),)

    return aggregate
